"""repro.ccltrace tests: span ring buffers, the adaptive-deadline rule,
the CCL-D culprit/victim decision table, the SimCluster hang surface,
end-to-end watchdog runs, and the GuardStepHook liveness path."""
import numpy as np
import pytest

from repro.ccltrace import (CollectiveSpanTrace, HangRole, HangWatchdog,
                            PendingCollective, SpanWindow, WatchdogConfig,
                            adaptive_deadline)
from repro.guard import GuardStepHook
from repro.simcluster import (DeadlockedCollective, FaultKind, FaultRates,
                              PartialNicBrownout, RunConfig, SimCluster,
                              StragglerTimeoutCascade, Tier, simulate_run)
from repro.simcluster.faults import HANG_NEVER_ENTER, HANG_STALLED

QUIET = FaultRates(thermal=0, power=0, mem_ecc=0, nic_down=0,
                   nic_degraded=0, host_cpu=0, congestion=0, fail_stop=0,
                   admission_grey_p=0)


def span(step, n=4, enter=1.0, exit_=2.0, ids=None, groups=None):
    ids = np.arange(n, dtype=np.int64) if ids is None else ids
    groups = np.zeros(n, np.int64) if groups is None else groups
    return SpanWindow(t=60.0 * step, step=step, op="all_reduce",
                      node_ids=ids, group_of=groups,
                      enter=np.full(n, float(enter)),
                      exit=np.full(n, float(exit_)))


def pending(n=4, entered=None, suspect=None, groups=None, completed=None,
            t_start=0.0):
    entered = np.ones(n, bool) if entered is None else entered
    suspect = np.zeros(n, bool) if suspect is None else suspect
    groups = np.zeros(n, np.int64) if groups is None else groups
    completed = np.zeros(n, bool) if completed is None else completed
    return PendingCollective(
        t_start=t_start, step=10, op="all_reduce",
        node_ids=np.arange(n, dtype=np.int64), group_of=groups,
        entered=entered,
        enter_t=np.where(entered, t_start + 1.0, np.inf),
        completed=completed, nic_suspect=suspect)


# --------------------------------------------------------------- spans


class TestSpanTrace:
    def test_circular_rotation_keeps_depth_rows(self):
        tr = CollectiveSpanTrace(depth=3)
        for s in range(5):
            tr.push(span(s, enter=1.0, exit_=2.0 + s))
        assert len(tr) == 3 and tr.full
        # order-invariant view holds exactly the last `depth` windows
        assert sorted(tr.rows("exit")[:, 0]) == [4.0, 5.0, 6.0]
        assert tr.last().step == 4

    def test_duration_and_trailing(self):
        tr = CollectiveSpanTrace(depth=4)
        tr.push(span(0, enter=1.0, exit_=3.0))
        tr.push(span(1, enter=1.0, exit_=6.0))
        np.testing.assert_array_equal(tr.duration_rows()[:, 0], [2.0, 5.0])
        np.testing.assert_array_equal(tr.trailing_duration(),
                                      np.full(4, 5.0))

    def test_resize_reallocates_and_bumps_generation(self):
        tr = CollectiveSpanTrace(depth=3)
        tr.push(span(0, n=4))
        g = tr.generation
        tr.push(span(1, n=6))
        assert tr.generation == g + 1
        assert len(tr) == 1 and tr.node_count == 6

    def test_same_size_swap_backfills_changed_column_only(self):
        tr = CollectiveSpanTrace(depth=3)
        tr.push(span(0, enter=1.0, exit_=2.0))
        tr.push(span(1, enter=1.0, exit_=4.0))
        ids = np.arange(4, dtype=np.int64)
        ids[2] = 99                          # node 2 swapped for spare 99
        tr.push(span(2, enter=1.0, exit_=8.0, ids=ids))
        assert tr.node_ids[2] == 99
        # the swapped-in column's history is the new node's value — it
        # never inherits its predecessor's spans; others keep theirs
        assert set(tr.rows("exit")[:, 2]) == {8.0}
        assert sorted(tr.rows("exit")[:, 0]) == [2.0, 4.0, 8.0]

    def test_group_of_tracks_latest_push(self):
        tr = CollectiveSpanTrace(depth=2)
        tr.push(span(0, groups=np.array([0, 0, 1, 1])))
        np.testing.assert_array_equal(tr.group_of, [0, 0, 1, 1])


# ------------------------------------------------------------- deadline


class TestAdaptiveDeadline:
    def test_clamp_rule(self):
        assert adaptive_deadline(10.0, 8.0, 30.0, 600.0) == 80.0
        assert adaptive_deadline(1.0, 8.0, 30.0, 600.0) == 30.0   # floor
        assert adaptive_deadline(500.0, 8.0, 30.0, 600.0) == 600.0  # cap

    def test_cold_trace_falls_back_to_default(self):
        wd = HangWatchdog(cfg=WatchdogConfig(default_deadline_s=120.0))
        assert wd.group_deadline_s(None) == 120.0
        assert wd.group_deadline_s(10.0) == 80.0

    def test_min_history_gates_adaptive_rule(self):
        tr = CollectiveSpanTrace(depth=4)
        wd = HangWatchdog(tr, WatchdogConfig(min_history=2,
                                             default_deadline_s=120.0))
        tr.push(span(0))
        assert wd._trailing(pending()) is None          # 1 < min_history
        tr.push(span(1))
        assert wd._trailing(pending()) is not None


# -------------------------------------------------- decision table


class TestClassification:
    def cfg(self):
        return WatchdogConfig(default_deadline_s=60.0)

    def test_never_entered_is_culprit_arrivers_are_victims(self):
        wd = HangWatchdog(cfg=self.cfg())
        p = pending(entered=np.array([True, False, True, True]))
        (v,) = wd.check(p, now=100.0)
        assert v.culprits == (1,) and sorted(v.victims) == [0, 2, 3]
        assert v.roles[1] is HangRole.CULPRIT_NEVER_ENTERED
        assert v.roles[0] is HangRole.VICTIM
        assert v.attributed

    def test_all_entered_with_link_evidence_is_stalled_culprit(self):
        wd = HangWatchdog(cfg=self.cfg())
        p = pending(suspect=np.array([False, False, True, False]))
        (v,) = wd.check(p, now=100.0)
        assert v.culprits == (2,)
        assert v.roles[2] is HangRole.CULPRIT_STALLED

    def test_all_entered_no_evidence_detects_without_attributing(self):
        """Everyone arrived, no link evidence: nobody is accused —
        detection without attribution beats a false eviction."""
        wd = HangWatchdog(cfg=self.cfg())
        (v,) = wd.check(pending(), now=100.0)
        assert v.culprits == ()
        assert sorted(v.victims) == [0, 1, 2, 3]
        assert not v.attributed

    def test_completed_group_excluded_from_verdict(self):
        wd = HangWatchdog(cfg=self.cfg())
        groups = np.array([0, 0, 1, 1], np.int64)
        p = pending(groups=groups,
                    entered=np.array([True, False, True, True]),
                    completed=np.array([False, False, True, True]))
        verdicts = wd.check(p, now=100.0)
        assert len(verdicts) == 1 and verdicts[0].group == 0
        assert 2 not in verdicts[0].roles and 3 not in verdicts[0].roles

    def test_not_overdue_and_dedup(self):
        wd = HangWatchdog(cfg=self.cfg())
        p = pending()
        assert wd.check(p, now=30.0) == []              # under deadline
        assert len(wd.check(p, now=100.0)) == 1
        assert wd.check(p, now=200.0) == []             # already fired
        # a NEW hang (different onset) fires again
        assert len(wd.check(pending(t_start=500.0), now=600.0)) == 1


# ------------------------------------------------------ sim surface


class TestSimHangSurface:
    def cluster(self, **kw):
        kw.setdefault("rates", QUIET)
        return SimCluster(n_active=8, n_spare=2, **kw)

    def test_collective_hang_sets_phase_and_wedges_window(self):
        c = self.cluster()
        c.injector.inject(FaultKind.COLLECTIVE_HANG, 3, device=-1,
                          severity=1.0)
        assert c.fleet.hang_phase[3] == HANG_NEVER_ENTER
        win = c.run_window(6)
        assert win["hung"] and win["steps_run"] == 0

    def test_brownout_severity_controls_hang(self):
        c = self.cluster()
        c.injector.inject(FaultKind.NIC_BROWNOUT, 2, device=0,
                          severity=0.9)
        c.injector.inject(FaultKind.NIC_BROWNOUT, 5, device=0,
                          severity=0.2)
        assert c.fleet.hang_phase[2] == HANG_STALLED
        assert c.fleet.hang_phase[5] == 0   # mild brownout: slow, not hung

    def test_phase_clears_when_fault_reverts(self):
        c = self.cluster()
        f = c.injector.inject(FaultKind.COLLECTIVE_HANG, 3, device=-1)
        c.injector._revert(f)
        assert c.fleet.hang_phase[3] == 0
        assert not c.run_window(6)["hung"]

    def test_hang_pending_snapshot(self):
        c = self.cluster()
        c.injector.inject(FaultKind.COLLECTIVE_HANG, 1, device=-1)
        pend = c.hang_pending()
        assert pend is not None
        row = int(np.flatnonzero(pend.node_ids == 1)[0])
        assert not pend.entered[row] and np.isinf(pend.enter_t[row])
        assert pend.entered[[i for i in range(8) if i != row]].all()

    def test_entered_stalled_hang_carries_link_evidence(self):
        """A device>=0 wedge must leave observable NIC evidence, or the
        all-entered verdict could never attribute."""
        c = self.cluster()
        c.injector.inject(FaultKind.COLLECTIVE_HANG, 4, device=1)
        pend = c.hang_pending()
        row = int(np.flatnonzero(pend.node_ids == 4)[0])
        assert pend.entered[row] and pend.nic_suspect[row]

    def test_probes_fail_while_wedged_scalar_and_batch_identical(self):
        c = self.cluster()
        c.injector.inject(FaultKind.COLLECTIVE_HANG, 3, device=-1)
        assert c.compute_probe(3, 0, 1.0) == 0.0
        batch = c.batch_compute_probe([2, 3, 4], 1.0)
        # exact zeros for the wedged node keep the batched-vs-scalar
        # bit-identity contract; healthy rows stay live
        assert (batch[1] == 0.0).all()
        assert (batch[0] > 0.0).all() and (batch[2] > 0.0).all()

    def test_span_feed_from_run_window(self):
        c = self.cluster()
        tr = CollectiveSpanTrace(depth=4)
        c.attach_spans(tr)
        for _ in range(3):
            c.run_window(6)
            c.collect()
        assert len(tr) == 3 and tr.node_count == 8
        # enter precedes exit everywhere: durations strictly positive
        assert (tr.duration_rows() > 0).all()


# ---------------------------------------------------- end-to-end


class TestEndToEnd:
    def run(self, scen, hours=3.0, watchdog=True):
        return simulate_run(RunConfig(
            tier=Tier.ENHANCED, n_nodes=32, n_spare=6, duration_h=hours,
            dp_group_size=8, diagnose=True, hang_watchdog=watchdog,
            initial_grey_p=0.0, rates=QUIET, scenarios=(scen,), seed=11))

    def test_deadlock_attributed_and_evicted(self):
        r = self.run(DeadlockedCollective(at_h=0.5, count=1))
        hangs = [e for e in r.events if e["kind"] == "hang"]
        assert hangs
        truth = {f["node"] for f in r.fault_log
                 if f["kind"] == "collective_hang"}
        culprits = {c for e in hangs for c in e["culprits"]}
        assert culprits == truth
        evicted = {e["old"] for e in r.events
                   if e["kind"] == "swap" and "hang" in e["reason"]}
        assert truth <= evicted
        # the job kept training after the eviction
        assert r.steps > 200

    def test_victims_watched_never_evicted(self):
        r = self.run(PartialNicBrownout(at_h=0.5, group_size=4))
        hangs = [e for e in r.events if e["kind"] == "hang"]
        assert hangs
        # within one verdict culprits and victims are disjoint
        for e in hangs:
            assert not (set(e["culprits"]) & set(e["victims"]))
        # every hang-reason eviction hit a genuinely faulted node: ranks
        # that never carried a hang-class fault (pure barrier victims)
        # are never pulled
        faulted = {f["node"] for f in r.fault_log
                   if f["kind"] in ("collective_hang", "nic_brownout")}
        hang_swaps = {e["old"] for e in r.events
                      if e["kind"] == "swap" and "hang" in e["reason"]}
        assert hang_swaps <= faulted
        victims = {v for e in hangs for v in e["victims"]}
        assert not ((victims - faulted) & hang_swaps)
        # hang-victim diagnoses were held, not evicted
        held = [e for e in r.events if e["kind"] == "diagnosis"
                and e["root_cause"] == "hang_victim"]
        assert all(e["held"] for e in held)

    def test_cascade_slow_then_hang(self):
        # short lag: the wedge must land before online detection evicts
        # the thermal straggler (a long prologue lets the z-path win)
        r = self.run(StragglerTimeoutCascade(at_h=0.5, count=1,
                                             lag_h=0.02))
        hangs = [e for e in r.events if e["kind"] == "hang"]
        assert hangs
        assert all(e["latency_windows"] <= 3.0 for e in hangs)

    def test_no_watchdog_rides_out_blind_ccl_timeout(self):
        r = self.run(DeadlockedCollective(at_h=0.5, count=1),
                     watchdog=False)
        blind = [e for e in r.events if e["kind"] == "restart"
                 and "CCL timeout" in e["reason"]]
        assert blind                       # legacy behavior preserved
        assert not [e for e in r.events if e["kind"] == "hang"]

    def test_deterministic(self):
        cfg = RunConfig(tier=Tier.ENHANCED, n_nodes=24, n_spare=4,
                        duration_h=2.0, dp_group_size=8, diagnose=True,
                        hang_watchdog=True, initial_grey_p=0.0,
                        rates=QUIET, seed=5,
                        scenarios=(DeadlockedCollective(at_h=0.5,
                                                        count=1),))
        a, b = simulate_run(cfg), simulate_run(cfg)
        assert a.events == b.events and a.steps == b.steps


# ------------------------------------------------------ hook liveness


class TestHookLiveness:
    def hook(self, **kw):
        kw.setdefault("window_steps", 3)
        kw.setdefault("warmup_windows", 0)
        return GuardStepHook(node_id=0, n_peers=7, **kw)

    def test_deadline_floor_before_baseline(self):
        h = self.hook(step_deadline_s=200.0)
        assert h.step_deadline() == 200.0

    def test_deadline_adapts_to_baseline(self):
        h = self.hook()
        for s in range(6):
            h(s, 10.0, {})
        # baseline ~10 s -> deadline = clamp(8 * 10, 300, 3600) = floor
        assert h.step_deadline() == 300.0
        h2 = self.hook(step_deadline_s=30.0)
        for s in range(6):
            h2(s, 10.0, {})
        assert h2.step_deadline() == pytest.approx(80.0, rel=0.2)

    def test_fresh_steps_keep_liveness_quiet(self):
        h = self.hook()
        for s in range(6):
            h(s, 10.0, {})
        assert not h.check_liveness()
        assert h.hangs_detected == 0

    def test_silence_past_deadline_fires_hang_and_restart(self):
        h = self.hook(step_deadline_s=100.0)
        for s in range(6):
            h(s, 10.0, {})
        h.control.t += 101.0               # a step never completes
        assert h.check_liveness()
        assert h.hangs_detected == 1 and h.restarts_requested == 1
        hangs = h.session.trace.of_kind("hang")
        assert len(hangs) == 1
        ev = hangs[0]
        assert ev.op == "step" and ev.victims == (0,)
        assert ev.culprits == ()           # single-host view: no blame
        assert ev.waited_s >= ev.deadline_s
        # firing resets the clock: no immediate double-fire
        assert not h.check_liveness()

    def test_restart_resets_liveness_clock(self):
        h = self.hook(step_deadline_s=100.0)
        for s in range(6):
            h(s, 10.0, {})
        h.control.t += 99.0
        h.on_restart(6)
        h.control.t += 50.0                # 50 s since restart, not 149
        assert not h.check_liveness()
