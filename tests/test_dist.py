"""Distribution-layer tests: logical-axis resolution, divisibility
fallback, sharded-vs-single-device numerical equivalence on a CPU mesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, reduced
from repro.dist import api as dist
from repro.launch.mesh import make_cpu_mesh
from repro.models.model import Model
from repro.train import AdamWConfig, init_opt_state, make_train_step
from repro.train.data import DataConfig, SyntheticLM


class TestSpecResolution:
    def setup_method(self):
        self.mesh = make_cpu_mesh()
        self.ctx = dist.DistContext(self.mesh)

    def test_basic_mapping(self):
        spec = self.ctx.spec(("fsdp", "tp"))
        assert spec == P("data", "model")

    def test_divisibility_fallback(self):
        # 12 heads on a model=1 CPU mesh always divides; fake a bigger mesh
        spec = self.ctx.spec(("heads", None), shape=(12, 64))
        assert spec == P("model", None)   # 12 % 1 == 0

    def test_none_replicates(self):
        assert self.ctx.spec((None, None)) == P(None, None)

    def test_duplicate_axis_suppressed(self):
        # two dims mapping to the same mesh axis: second one replicates
        spec = self.ctx.spec(("tp", "ff"))
        assert spec == P("model", None)

    def test_constraint_noop_without_context(self):
        dist.set_context(None)
        x = jnp.ones((4, 4))
        y = dist.constraint(x, "act_batch", None)
        assert y is x


class TestDivisibilityFallbackBigMesh:
    def test_whisper_heads_replicate_on_16(self):
        """12 heads don't divide a 16-way model axis -> replicated."""
        # simulate the rule logic without devices: use a fake mesh shape
        ctx = dist.DistContext(make_cpu_mesh())
        # direct unit check of the divisibility branch
        spec = ctx.spec(("heads",), shape=(12,))
        assert spec == P("model")  # divides on 1-wide CPU mesh
        # the real 16-wide check is exercised by the dry-run (whisper cells)


@pytest.mark.slow
class TestShardedEquivalence:
    @pytest.mark.parametrize("arch", ["qwen3-4b", "deepseek-moe-16b",
                                      "rwkv6-7b", "recurrentgemma-9b"])
    def test_train_step_matches_unsharded(self, arch):
        cfg = reduced(get_config(arch))
        model = Model(cfg)
        data = SyntheticLM(DataConfig(cfg.vocab_size, 16, 2))
        params, axes = model.init_params(jax.random.key(0))
        opt = init_opt_state(params)
        batch = data.batch_at(0)
        step = make_train_step(model, AdamWConfig())

        _, _, m_plain = jax.jit(step)(params, opt, batch)

        mesh = make_cpu_mesh()
        with mesh, dist.use_mesh(mesh):
            step_fn = make_train_step(model, AdamWConfig())
            _, _, m_mesh = jax.jit(step_fn)(params, opt, batch)

        assert float(m_plain["loss"]) == pytest.approx(
            float(m_mesh["loss"]), rel=1e-4), arch

    def test_decode_matches_unsharded(self):
        cfg = reduced(get_config("glm4-9b"))
        model = Model(cfg)
        params, _ = model.init_params(jax.random.key(2))
        cache = model.init_cache(2, 32)
        tok = jnp.asarray([3, 5], jnp.int32)
        logits_plain, _ = jax.jit(model.decode_step)(params, tok, cache)
        mesh = make_cpu_mesh()
        with mesh, dist.use_mesh(mesh):
            logits_mesh, _ = jax.jit(model.decode_step)(params, tok, cache)
        np.testing.assert_allclose(np.asarray(logits_plain, np.float32),
                                   np.asarray(logits_mesh, np.float32),
                                   atol=1e-2, rtol=1e-3)


class TestHLOAnalysis:
    def test_scan_trip_count_multiplies_flops(self):
        from repro.launch.hlo_analysis import analyze

        def f(a):
            def body(c, _):
                return c @ c, None
            c, _ = jax.lax.scan(body, a, None, length=7)
            return jnp.sum(c)

        compiled = jax.jit(f).lower(
            jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
        mc = analyze(compiled.as_text())
        per_mm = 2 * 64 ** 3
        assert mc.flops == pytest.approx(7 * per_mm, rel=0.05)

    def test_collectives_counted(self):
        from repro.launch.hlo_analysis import analyze
        mesh = make_cpu_mesh()

        def f(x):
            return jnp.sum(x)

        compiled = jax.jit(f).lower(
            jax.ShapeDtypeStruct((64,), jnp.float32)).compile()
        mc = analyze(compiled.as_text())
        assert mc.collective_bytes >= 0.0   # no mesh: none expected
