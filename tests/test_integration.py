"""End-to-end integration: the full Guard closed loop over a simulated
fleet, and the real-JAX sweep backend running the Pallas burn kernel."""
import numpy as np

from repro.core import SweepConfig, single_node_sweep
from repro.kernels.sweep_burn import LocalJaxSweepBackend
from repro.simcluster import FaultRates, RunConfig, Tier, simulate_run


class TestClosedLoopEndToEnd:
    def test_full_run_mitigates_injected_greys(self):
        """A run with a known grey population: Guard must remove most of
        the step-time inflation within the first simulated hours."""
        cfg = RunConfig(tier=Tier.ENHANCED, n_nodes=48, n_spare=8,
                        duration_h=8.0, initial_grey_p=0.25, seed=3)
        r = simulate_run(cfg)
        healthy = cfg.workload.healthy_step_s
        first_hour = r.step_times[: int(3600 / healthy)]
        last_hours = r.step_times[len(r.step_times) // 2:]
        assert np.mean(last_hours) < np.mean(first_hour)
        assert np.mean(last_hours) < healthy * 1.15

    def test_tier_ordering_on_mfu(self):
        mfus = {}
        for tier in Tier:
            r = simulate_run(RunConfig(tier=tier, n_nodes=48, n_spare=8,
                                       duration_h=10.0, initial_grey_p=0.2,
                                       seed=0))
            mfus[int(tier)] = r.mfu
        assert mfus[4] > mfus[1]
        assert mfus[3] > mfus[2] > mfus[1]

    def test_no_fault_run_is_clean(self):
        quiet = FaultRates(thermal=0, power=0, mem_ecc=0, nic_down=0,
                           nic_degraded=0, host_cpu=0, congestion=0,
                           fail_stop=0, admission_grey_p=0)
        r = simulate_run(RunConfig(tier=Tier.ENHANCED, n_nodes=32,
                                   n_spare=4, duration_h=4.0,
                                   initial_grey_p=0.0, rates=quiet, seed=1))
        assert r.crashes == 0
        assert r.guard_restarts == 0
        assert r.mfu > 0.19           # ~mfu_at_healthy


class TestLocalJaxBackend:
    def test_real_sweep_on_local_device(self):
        """The deployable path: the §5.2 sweep driving the actual Pallas
        burn kernel on this host's device."""
        backend = LocalJaxSweepBackend(interpret=True)
        ref = backend.reference()
        assert ref.device_tflops > 0
        rep = single_node_sweep(
            backend, node_id=0,
            cfg=SweepConfig(burn_seconds=8.0, compute_tolerance=0.5,
                            symmetry_tolerance=0.5, bw_tolerance=0.9),
        )
        assert rep.measurements["tflops"].shape[0] == \
            backend.device_count(0)
