"""Property-based tests (hypothesis) on system invariants."""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.detector import DetectorConfig, StragglerDetector, robust_z
from repro.core.sweep import SweepCampaign, fleet_qualification
from repro.core.telemetry import Frame
from repro.simcluster import (DeadlockedCollective, FaultKind, FaultRates,
                              PartialNicBrownout, RunConfig, SimCluster,
                              StragglerTimeoutCascade, Tier, freq_at_temp,
                              simulate_run)
from repro.train.data import DataConfig, SyntheticLM

QUIET = FaultRates(thermal=0, power=0, mem_ecc=0, nic_down=0,
                   nic_degraded=0, host_cpu=0, congestion=0, fail_stop=0,
                   admission_grey_p=0)


def frame(step, times):
    n = len(times)
    return Frame(t=float(step), step=step,
                 node_ids=np.arange(n, dtype=np.int64),
                 metrics={"step_time": np.asarray(times, float)},
                 valid=np.ones(n, bool))


# ------------------------------------------------------------- detector


@given(st.integers(8, 64), st.floats(1.0, 100.0), st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_robust_z_shift_invariant(n, base, seed):
    rng = np.random.RandomState(seed)
    v = rng.normal(0, 1, n)
    z1 = robust_z(v)
    z2 = robust_z(v + base)
    np.testing.assert_allclose(z1, z2, atol=1e-6)


@given(st.integers(8, 40), st.floats(0.3, 3.0), st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_uniform_fleet_never_flagged(n, scale, seed):
    """Identical nodes (pure iid noise) must not produce step flags."""
    rng = np.random.RandomState(seed)
    det = StragglerDetector(DetectorConfig(window=6, persistence=3))
    flagged = False
    for w in range(10):
        times = 10.0 * scale * (1 + rng.normal(0, 0.005, n))
        res = det.update(frame(w, times))
        flagged |= any(a.step_deviant for a in res)
    assert not flagged


@given(st.integers(8, 40), st.floats(0.15, 0.8), st.integers(0, 1000))
@settings(max_examples=25, deadline=None)
def test_big_sustained_straggler_always_flagged(n, excess, seed):
    rng = np.random.RandomState(seed)
    det = StragglerDetector()
    bad = seed % n
    for w in range(8):
        times = 10.0 * (1 + rng.normal(0, 0.005, n))
        times[bad] *= 1 + excess
        res = det.update(frame(w, times))
    assert res[bad].flagged
    # estimated slowdown within 30% of injected
    assert abs(res[bad].slowdown - excess) / excess < 0.3


# ------------------------------------------------------------- simcluster


@given(st.floats(0.0, 120.0))
@settings(max_examples=50, deadline=None)
def test_throttle_curve_bounded(temp):
    f = float(freq_at_temp(np.array([temp]))[0])
    assert 0.9 <= f <= 1.93


@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_comm_factor_bounds(seed):
    """Comm factor is in (0, 1]: reroute can only slow a node down."""
    rng = np.random.RandomState(seed)
    c = SimCluster(n_active=8, n_spare=0, rates=QUIET, seed=seed)
    for _ in range(rng.randint(1, 6)):
        kind = [FaultKind.NIC_DOWN, FaultKind.NIC_DEGRADED][rng.randint(2)]
        c.injector.inject(kind, int(rng.randint(8)),
                          severity=float(rng.rand()),
                          device=int(rng.randint(8)))
    f = c.fleet.node_comm_factor()
    assert np.all(f <= 1.0 + 1e-9) and np.all(f > 0)


@given(st.integers(0, 10_000))
@settings(max_examples=15, deadline=None)
def test_traffic_conservation_under_reroute(seed):
    """Total transmitted bytes are preserved by rerouting (traffic moves,
    it doesn't disappear) while any link is up."""
    rng = np.random.RandomState(seed)
    c = SimCluster(n_active=4, n_spare=0, rates=QUIET, seed=seed)
    n_down = rng.randint(0, 7)
    for d in rng.choice(8, n_down, replace=False):
        c.injector.inject(FaultKind.NIC_DOWN, 1, device=int(d))
    c.fleet.account_traffic(1.0)
    total = c.fleet.nic_tx_bytes.sum(axis=1)
    np.testing.assert_allclose(total, 8.0)


@given(st.integers(0, 500))
@settings(max_examples=10, deadline=None)
def test_step_time_lower_bounded_by_healthy(seed):
    """Faults can only ever slow the job down."""
    rng = np.random.RandomState(seed)
    c = SimCluster(n_active=8, n_spare=0, rates=QUIET, seed=seed)
    healthy = c.workload.healthy_step_s
    for _ in range(rng.randint(0, 4)):
        kind = list(FaultKind)[rng.randint(6)]
        c.injector.inject(kind, int(rng.randint(8)),
                          severity=float(rng.rand()))
    c.fleet.advance_thermals(3600)
    t = c.node_barrier_times()
    assert t.max() >= healthy * 0.95


# ------------------------------------------------------------- ccltrace


@given(st.integers(0, 2), st.integers(0, 1000),
       st.sampled_from(["none", "rack_thermal", "congestion_storm",
                        "maintenance_window"]))
@settings(max_examples=8, deadline=None)
def test_hang_watchdog_invariants_under_composition(which, seed, extra):
    """Random hang scenario composed with a random pre-existing fault
    scenario: the watchdog must (1) leak no nodes between pools, (2)
    never evict a rank that never carried a hang-class fault, and (3)
    resolve every injected deadlock — attributed-and-evicted, or the
    node left the job some other way (crash/eviction) first."""
    hang = [DeadlockedCollective(at_h=0.4, count=1 + seed % 2,
                                 interval_h=0.4),
            PartialNicBrownout(at_h=0.4, group_size=4),
            StragglerTimeoutCascade(at_h=0.4, count=1, lag_h=0.02)][which]
    scenarios = (hang,) if extra == "none" else (hang, extra)
    r = simulate_run(RunConfig(
        tier=Tier.ENHANCED, n_nodes=16, n_spare=4, duration_h=2.5,
        dp_group_size=8, diagnose=True, hang_watchdog=True,
        initial_grey_p=0.0, rates=QUIET, scenarios=scenarios, seed=seed))

    # (1) pool conservation: the job is always full at run end, and the
    # census never invents or loses nodes
    assert r.pools.get("active", 0) == 16
    assert all(v >= 0 for v in r.pools.values())

    # (2) no hang-victim eviction: every hang-reason swap pulled a node
    # that genuinely carried a hang-class fault at some point
    faulted = {f["node"] for f in r.fault_log
               if f["kind"] in ("collective_hang", "nic_brownout")}
    hang_swaps = {e["old"] for e in r.events
                  if e["kind"] == "swap" and "hang" in e["reason"]}
    assert hang_swaps <= faulted

    # (3) every injected deadlock resolves: culprit-attributed, or the
    # node was already out of the job (evicted/crashed) when it fired
    culprits = {c for e in r.events if e["kind"] == "hang"
                for c in e["culprits"]}
    gone = {e["old"] for e in r.events if e["kind"] == "swap"} | \
        {n for e in r.events if e["kind"] == "crash"
         for n in e["nodes"]}
    for f in r.fault_log:
        if f["kind"] == "collective_hang":
            assert f["node"] in culprits | gone


# ------------------------------------------------------- fleet scale


@pytest.mark.scale
@given(st.integers(0, 1000), st.integers(0, 2))
@settings(max_examples=3, deadline=None)
def test_scale_fault_hang_composition_invariants(seed, which):
    """8k-node run composing background Poisson fault churn with a
    random hang scenario, then a batched qualification campaign over
    the survivors. Invariants at fleet scale:

      1. pool census conservation (no spare leak): every node the run
         started with or provisioned is in exactly one pool at the end;
      2. no never-faulted eviction: every swapped-out node carried at
         least one logged fault (hang victims / congestion transients
         are held, never pulled);
      3. campaign convergence: fleet qualification over a fleet-scale
         candidate set terminates with exactly one verdict per node
         within the two-stage + one-retry sweep budget."""
    hang = [DeadlockedCollective(at_h=0.5, count=1 + seed % 2,
                                 interval_h=0.5),
            PartialNicBrownout(at_h=0.5, group_size=8),
            StragglerTimeoutCascade(at_h=0.5, count=1, lag_h=0.02)][which]
    n, spares = 8192, 64
    r = simulate_run(RunConfig(
        tier=Tier.ENHANCED, n_nodes=n, n_spare=spares, duration_h=1.5,
        dp_group_size=256, diagnose=True, hang_watchdog=True,
        rates=FaultRates(), scenarios=(hang,), seed=seed))

    # (1) census conservation
    provisioned = sum(1 for e in r.events if e["kind"] == "provision")
    assert sum(r.pools.values()) == n + spares + provisioned
    assert all(v >= 0 for v in r.pools.values())

    # (2) only genuinely faulted hardware is ever pulled
    faulted = {f["node"] for f in r.fault_log}
    swapped = {e["old"] for e in r.events if e["kind"] == "swap"}
    assert swapped <= faulted, swapped - faulted

    # (3) batched campaign over a fleet-scale candidate set converges
    c = SimCluster(n, 0, reserve=0, rates=QUIET, seed=seed + 1)
    for node in sorted(faulted)[:64]:
        if node < n:
            kind = [FaultKind.THERMAL, FaultKind.POWER,
                    FaultKind.NIC_DEGRADED][node % 3]
            c.injector.inject(kind, node, severity=0.9)
    c.fleet.advance_thermals(3600.0)
    campaign = SweepCampaign(node_ids=tuple(range(n)))
    res = fleet_qualification(c.sweep_backend, campaign)
    assert len(res.reports) == n
    assert [rep.node_id for rep in res.reports] == list(range(n))
    # sweep budget: stage 1 once per node, stage 2 once per candidate,
    # plus at most one disjoint-buddy retry per failing group
    assert res.sweeps <= 3 * n
    # the healthy majority qualifies; severe planted faults do not pass
    assert len(res.passed) >= n - 3 * 64


# ------------------------------------------------------------- data


@given(st.integers(0, 50), st.integers(1, 4))
@settings(max_examples=15, deadline=None)
def test_data_determinism_and_sharding(step, shards):
    cfg = DataConfig(vocab_size=1024, seq_len=32, global_batch=8)
    data = SyntheticLM(cfg)
    full = data.batch_at(step)
    again = data.batch_at(step)
    np.testing.assert_array_equal(full["tokens"], again["tokens"])
    if 8 % shards == 0:
        parts = [data.batch_at(step, s, shards)["tokens"]
                 for s in range(shards)]
        np.testing.assert_array_equal(np.concatenate(parts), full["tokens"])
    assert full["tokens"].min() >= 0
    assert full["tokens"].max() < cfg.vocab_size
    np.testing.assert_array_equal(full["labels"][:, :-1],
                                  full["tokens"][:, 1:])


# ------------------------------------------------------------- fleet


@given(st.integers(2, 4),                       # concurrent jobs
       st.lists(st.tuples(st.integers(0, 3),   # job index (mod n_jobs)
                          st.integers(0, 4)),  # op selector
                min_size=4, max_size=30),
       st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_fleet_pool_contention_invariants(n_jobs, ops, seed):
    """N random-priority jobs over ONE shared pool: (1) the fleet-wide
    node census is conserved after every operation, (2) no home node is
    ever granted more times than it was handed to the pool (no lease
    double-grant), (3) every job with a pending request is served once
    the controller ticks (no starvation)."""
    from repro.fleet import FleetController
    from repro.guard.session import GuardSession, Tier

    rng = np.random.RandomState(seed)
    ctl = FleetController(bench_slots=2, starvation_age_s=1e9)
    jobs = []
    for i in range(n_jobs):
        c = SimCluster(8, n_spare=int(rng.randint(0, 4)), rates=QUIET,
                       seed=seed + i)
        s = GuardSession.from_tier(Tier.ONLINE, c, c)
        s.register_active(c.active)
        s.register_spares(c.spares)
        ctl.register_job(f"j{i}", s, priority=int(rng.randint(1, 5)))
        jobs.append(s)
    kinds = ["swap", "crash", "hang"]
    held = [[] for _ in range(n_jobs)]
    requests = []
    t = 0.0
    for j_raw, op in ops:
        j = j_raw % n_jobs
        t += 1.0
        if op <= 2:                           # synchronous lease
            held[j].append(jobs[j].take_spare(kind=kinds[op]))
        elif op == 3 and held[j]:             # hand a node back
            jobs[j].return_spare(held[j].pop())
        else:                                 # queued ask
            requests.append(ctl.request_spare(f"j{j}", kinds[op % 3]))
        cen = ctl.census()
        assert cen["conserved"], cen

    # (2) replay the event stream: per home fleet, a node is never
    # granted from the free pool more often than it entered it
    gives = {}
    grants = {}
    for rec in ctl.log.subscribe(after=0)[0]:
        key = (rec.job, rec.event.to_dict().get("node_id"))
        if rec.event.kind == "spare_reclaimed":
            gives[key] = gives.get(key, 0) + 1
        elif rec.event.kind == "spare_leased":
            d = rec.event.to_dict()
            if not d["provisioned"] and not d["transfer"]:
                grants[key] = grants.get(key, 0) + 1
                assert grants[key] <= gives.get(key, 0), \
                    f"double grant of {key}"

    # (3) a tick serves every queued request (provisioning keeps the
    # pool from deadlocking); nobody is left pending
    ctl.tick(t + 1.0)
    assert all(r.served for r in requests)
    assert not ctl.pool.pending()
    assert ctl.census()["conserved"]
    assert ctl.starvation_events() == 0
