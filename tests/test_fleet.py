"""Fleet control plane: global pool arbitration, lease/grant protocol,
shared sweep bench, healthscan campaigns, cursor-replay event stream,
and the multi-job sim driver."""
import io

import pytest

from repro.core.health_manager import NodeState
from repro.fleet import (FleetController, FleetEventLog, GlobalSparePool,
                         LeaseKind, SSEStreamSink)
from repro.guard.events import NodeSwapped
from repro.guard.session import GuardSession, Tier
from repro.simcluster import (FaultKind, FaultRates, FleetJobSpec,
                              FleetRunConfig, SimCluster, simulate_fleet)

QUIET = FaultRates(thermal=0, power=0, mem_ecc=0, nic_down=0,
                   nic_degraded=0, host_cpu=0, congestion=0, fail_stop=0,
                   admission_grey_p=0)


def make_job(controller, name, tier=Tier.ENHANCED, n=32, n_spare=4,
             seed=0, priority=None, rates=QUIET):
    c = SimCluster(n, n_spare=n_spare, rates=rates, seed=seed)
    s = GuardSession.from_tier(tier, c, c)
    s.register_active(c.active)
    s.register_spares(c.spares)
    controller.register_job(name, s, priority=priority)
    return c, s


# ------------------------------------------------------------------- pool


class TestGlobalSparePool:
    def test_home_grant_preferred_over_transfer(self):
        pool = GlobalSparePool()
        pool.add(1, home="a", now=0.0)
        pool.add(2, home="b", now=1.0)
        lease = pool.grant("b", LeaseKind.SLOW_SWAP, now=2.0)
        assert lease.node_id == 2 and not lease.transfer

    def test_foreign_grant_is_transfer(self):
        pool = GlobalSparePool()
        pool.add(1, home="a", now=0.0)
        lease = pool.grant("b", LeaseKind.CRASH, now=2.0)
        assert lease.transfer and lease.home == "a"

    def test_dry_pool_returns_none(self):
        pool = GlobalSparePool()
        assert pool.grant("a", LeaseKind.SLOW_SWAP, now=0.0) is None

    def test_node_ids_namespaced_per_home(self):
        pool = GlobalSparePool()
        pool.add(7, home="a", now=0.0)
        pool.add(7, home="b", now=0.0)       # same id, different fleet
        assert pool.free_count() == 2
        with pytest.raises(AssertionError):
            pool.add(7, home="a", now=1.0)   # true double give

    def test_urgency_ladder_orders_queue(self):
        pool = GlobalSparePool()
        r_swap = pool.request("a", LeaseKind.SLOW_SWAP, priority=4, now=0.0)
        r_hang = pool.request("b", LeaseKind.HANG_EVICT, priority=3,
                              now=0.0)
        r_crash = pool.request("c", LeaseKind.CRASH, priority=3, now=0.0)
        for nid, home in [(1, "a"), (2, "b"), (3, "c")]:
            pool.add(nid, home=home, now=0.0)
        served = pool.serve(now=1.0)
        # hang > crash > swap regardless of priority
        assert [r.job for r in served] == ["b", "c", "a"]
        assert r_hang.served and r_crash.served and r_swap.served

    def test_priority_breaks_ties_within_kind(self):
        pool = GlobalSparePool()
        pool.request("low", LeaseKind.SLOW_SWAP, priority=3, now=0.0)
        pool.request("high", LeaseKind.SLOW_SWAP, priority=4, now=0.0)
        pool.add(1, home="low", now=0.0)
        pool.add(2, home="high", now=0.0)
        served = pool.serve(now=1.0)
        assert [r.job for r in served] == ["high", "low"]

    def test_fair_share_floor_outranks_priority(self):
        pool = GlobalSparePool(floor_frac=0.5)
        pool.register_job("big")
        pool.register_job("small")
        # "big" has hoarded grants; "small" is far below the floor
        for i in range(10):
            pool.add(100 + i, home="big", now=0.0)
            pool.grant("big", LeaseKind.SLOW_SWAP, now=0.0)
        pool.request("big", LeaseKind.HANG_EVICT, priority=4, now=0.0)
        pool.request("small", LeaseKind.SLOW_SWAP, priority=1, now=0.0)
        pool.add(1, home="small", now=0.0)
        served = pool.serve(now=1.0)
        # only one node free: the below-floor job gets it despite lower
        # priority AND lower urgency
        assert served[0].job == "small"

    def test_starvation_bound_outranks_everything(self):
        pool = GlobalSparePool(starvation_age_s=100.0)
        pool.request("old", LeaseKind.SLOW_SWAP, priority=1, now=0.0)
        pool.request("new", LeaseKind.HANG_EVICT, priority=4, now=190.0)
        pool.add(1, home="old", now=0.0)
        served = pool.serve(now=200.0)
        assert served[0].job == "old"
        # crossing the bound is also counted against the no-starvation
        # guarantee
        assert pool.stats.starvation_events == 1
        assert pool.stats.max_wait_s >= 200.0

    def test_materialize_keeps_serving_dry_pool(self):
        pool = GlobalSparePool()
        pool.request("a", LeaseKind.CRASH, priority=3, now=0.0)
        fresh = iter([50, 51])
        served = pool.serve(now=1.0, materialize=lambda job: next(fresh))
        assert served[0].lease.provisioned
        assert served[0].lease.node_id == 50


# ------------------------------------------------------------- controller


class TestFleetController:
    def test_registration_adopts_private_spares(self):
        ctl = FleetController(bench_slots=2)
        c, s = make_job(ctl, "a", n=16, n_spare=3)
        assert s.manager.spares == []          # drained
        assert ctl.pool.free_count(home="a") == 3
        assert s.manager.pool is not None
        assert s.scheduler.bench is ctl.bench
        assert ctl.census()["conserved"]

    def test_take_spare_leases_from_pool(self):
        ctl = FleetController(bench_slots=2)
        c, s = make_job(ctl, "a", n=16, n_spare=2)
        nid = s.take_spare(kind="crash")
        assert s.manager.state[nid] == NodeState.ACTIVE
        assert ctl.pool.free_count() == 1
        leased = ctl.log.subscribe(after=0)[0]
        kinds = [r.event.kind for r in leased]
        assert "spare_leased" in kinds
        assert ctl.census()["conserved"]

    def test_cross_job_grant_transfers_and_conserves(self):
        ctl = FleetController(bench_slots=2)
        make_job(ctl, "a", n=16, n_spare=0, seed=1)
        make_job(ctl, "b", n=16, n_spare=2, seed=2)
        cen0 = ctl.census()
        nid = ctl.jobs["a"].session.take_spare()
        assert ctl.jobs["a"].transfer_grants == 1
        assert len(ctl.ghosts) == 1
        cen = ctl.census()
        assert cen["conserved"]
        assert cen["expected"] == cen0["expected"] + 1  # one provision
        assert ctl.jobs["a"].session.manager.state[nid] == NodeState.ACTIVE

    def test_dry_pool_provisions(self):
        ctl = FleetController(bench_slots=2)
        make_job(ctl, "a", n=8, n_spare=0)
        nid = ctl.jobs["a"].session.take_spare()
        assert ctl.jobs["a"].provision_grants == 1
        assert nid in ctl.jobs["a"].session.manager.state
        assert ctl.census()["conserved"]

    def test_return_spare_lands_in_pool(self):
        ctl = FleetController(bench_slots=2)
        c, s = make_job(ctl, "a", n=16, n_spare=1)
        nid = s.take_spare()
        s.return_spare(nid)
        assert nid not in s.manager.state      # the pool owns it again
        assert ctl.pool.free_count(home="a") == 1
        assert ctl.census()["conserved"]

    def test_top_up_respects_home_floor(self):
        ctl = FleetController(bench_slots=2)
        make_job(ctl, "a", n=8, n_spare=0, seed=1)
        make_job(ctl, "b", n=8, n_spare=0, seed=2)
        added = ctl.top_up(global_target=6, home_min=2)
        assert added == 6
        assert ctl.pool.free_count(home="a") >= 2
        assert ctl.pool.free_count(home="b") >= 2
        assert ctl.pool.free_count() >= 6
        assert ctl.census()["conserved"]

    def test_quarantine_requalify_returns_to_pool(self):
        ctl = FleetController(bench_slots=2)
        c, s = make_job(ctl, "a", n=16, n_spare=4)
        bad = c.active[0]
        s.replace_node(bad, reason="test eviction", step=0)
        assert s.manager.state[bad] == NodeState.QUARANTINED
        free0 = ctl.pool.free_count()
        s.scheduler.drain(c.t, step=0)
        # healthy node requalifies back into the GLOBAL pool
        assert bad not in s.manager.state
        assert ctl.pool.free_count() == free0 + 1
        assert ctl.census()["conserved"]

    def test_shared_bench_serializes_two_jobs(self):
        ctl = FleetController(bench_slots=1)
        c1, s1 = make_job(ctl, "a", n=16, n_spare=4, seed=1)
        c2, s2 = make_job(ctl, "b", n=16, n_spare=4, seed=2)
        s1.replace_node(c1.active[0], reason="evict", step=0)
        s2.replace_node(c2.active[0], reason="evict", step=0)
        s1.scheduler.advance(0.0)
        s2.scheduler.advance(0.0)
        # one slot: at most one qualification in flight across BOTH jobs
        assert s1.scheduler.busy + s2.scheduler.busy == 1
        s1.scheduler.drain(1e9)
        s2.scheduler.drain(1e9)
        fin1 = [e for e in s1.events() if e.kind == "sweep_finish"]
        fin2 = [e for e in s2.events() if e.kind == "sweep_finish"]
        assert fin1 and fin2
        # the second job's sweep queued behind the first on the shared
        # slot: no time overlap is possible with one slot
        assert ctl.census()["conserved"]


# -------------------------------------------------------------- healthscan


class TestHealthscan:
    def test_periodic_campaign_scans_pool(self):
        ctl = FleetController(bench_slots=2, healthscan_period_s=100.0)
        c, s = make_job(ctl, "a", n=16, n_spare=4)
        c.advance_idle(150.0)
        ctl.tick()
        assert ctl.healthscan.campaigns == 1
        assert ctl.healthscan.scanned == 4
        ev = [r.event for r in ctl.log.subscribe(after=0)[0]
              if r.event.kind == "campaign_scheduled"]
        assert len(ev) == 1 and len(ev[0].nodes) == 4

    def test_grey_spare_pulled_and_quarantined(self):
        ctl = FleetController(bench_slots=2, healthscan_period_s=100.0)
        c, s = make_job(ctl, "a", n=16, n_spare=4)
        bad = ctl.pool.free_ids(home="a")[0]
        c.injector.inject(FaultKind.THERMAL, bad, now=c.t, severity=0.9)
        c.advance_idle(150.0)
        ctl.tick()
        assert bad in ctl.healthscan.failed
        assert s.manager.state[bad] == NodeState.QUARANTINED
        assert bad not in ctl.pool.free_ids(home="a")
        assert ctl.census()["conserved"]

    def test_busy_bench_defers_scan(self):
        ctl = FleetController(bench_slots=1, healthscan_period_s=100.0)
        c, s = make_job(ctl, "a", n=16, n_spare=4)
        # occupy the single slot far into the future
        ctl.bench.occupy(0.0, 1e6)
        c.advance_idle(150.0)
        ctl.tick()
        assert ctl.healthscan.campaigns == 0


# ------------------------------------------------------------ event stream


class TestFleetEventLog:
    def ev(self, i):
        return NodeSwapped(t=float(i), step=i, old=i, new=i + 1)

    def test_monotonic_seq_and_replay(self):
        log = FleetEventLog(capacity=100)
        for i in range(10):
            log.append("job0", self.ev(i))
        recs, lost = log.subscribe(after=0)
        assert [r.seq for r in recs] == list(range(1, 11))
        assert lost == 0
        # cursor resume mid-stream
        recs, lost = log.subscribe(after=7)
        assert [r.seq for r in recs] == [8, 9, 10]

    def test_ring_truncation_reports_lost(self):
        log = FleetEventLog(capacity=5)
        for i in range(12):
            log.append("job0", self.ev(i))
        recs, lost = log.subscribe(after=2)
        assert [r.seq for r in recs] == [8, 9, 10, 11, 12]
        assert lost == 5          # seqs 3-7 evicted
        assert log.tail == 8 and log.head == 12

    def test_limit_pagination(self):
        log = FleetEventLog(capacity=100)
        for i in range(10):
            log.append("job0", self.ev(i))
        page, _ = log.subscribe(after=0, limit=4)
        assert [r.seq for r in page] == [1, 2, 3, 4]
        page, _ = log.subscribe(after=page[-1].seq, limit=4)
        assert [r.seq for r in page] == [5, 6, 7, 8]

    def test_job_tags_and_sse_framing(self):
        log = FleetEventLog(capacity=100)
        buf = io.StringIO()
        log.attach(SSEStreamSink(buf))
        log.append("alpha", self.ev(0))
        log.append("beta", self.ev(1))
        recs, _ = log.subscribe(after=0)
        assert [r.job for r in recs] == ["alpha", "beta"]
        out = buf.getvalue()
        assert "id: 1\n" in out and "id: 2\n" in out
        assert "event: swap\n" in out
        assert '"job": "alpha"' in out

    def test_session_tap_aggregates_bus(self):
        ctl = FleetController(bench_slots=2)
        c, s = make_job(ctl, "a", n=16, n_spare=2)
        s.publish(self.ev(0))
        recs, _ = ctl.log.subscribe(after=0)
        assert any(r.event.kind == "swap" and r.job == "a" for r in recs)


# ------------------------------------------------------------- sim driver


class TestSimulateFleet:
    def test_two_jobs_conserved_no_starvation(self):
        cfg = FleetRunConfig(
            jobs=(FleetJobSpec("a", tier=Tier.ENHANCED, n_nodes=32,
                               n_spare=2, seed=1),
                  FleetJobSpec("b", tier=Tier.ONLINE, n_nodes=32,
                               n_spare=2, seed=2)),
            duration_h=3.0, spare_target=6, home_min=1,
            healthscan_period_s=3600.0, seed=5)
        res = simulate_fleet(cfg)
        assert res.census_ok
        assert res.starvation_events == 0
        assert res.events_logged > 0
        assert all(j["steps"] > 0 for j in res.jobs)
        assert 0.0 <= res.overhead_frac < 1.0

    def test_bench_slots_match_scheduler_view(self):
        cfg = FleetRunConfig(
            jobs=(FleetJobSpec("a", n_nodes=16, n_spare=2),),
            duration_h=1.0, bench_slots=3, spare_target=2, home_min=1,
            rates=QUIET, initial_grey_p=0.0, seed=1)
        res = simulate_fleet(cfg)
        assert res.census_ok and res.starvation_events == 0
