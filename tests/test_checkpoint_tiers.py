"""Tiered checkpointing and the async-writer/restart race.

Covers the recovery-architecture contract: the wait() semantics under
restart (an in-flight async snapshot either lands fully or is
discarded — never a torn or stale checkpoint), tier selection
(peer replica vs local shard vs cold), the bit-identical guarantee
across tiers, and the MTTF-driven cadence auto-tuner.
"""
import os
import tempfile

import numpy as np
import pytest

from repro.guard.goodput import CheckpointTier, RecoveryModel
from repro.train import CheckpointManager, TieredCheckpointManager


def tree(scale: float):
    """A small (params, opt) pair; ``scale`` distinguishes versions."""
    params = {"w": np.full((4, 3), scale), "b": np.arange(3.0) * scale}
    opt = {"mu": {"w": np.zeros((4, 3)), "b": np.zeros(3)},
           "count": np.asarray(int(scale))}
    return params, opt


def assert_tree_equal(a, b):
    import jax
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


class TestWaitRaceRegression:
    def test_resave_same_step_after_rewind_lands(self):
        """The crash/restore race: save step 10, rewind, retrain, save
        step 10 AGAIN. The second (async) write must replace the first —
        before the fix os.rename onto the existing non-empty dir raised
        ENOTEMPTY inside the daemon thread, was silently swallowed, and a
        later restore loaded the stale version."""
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, async_save=True)
            p1, o1 = tree(1.0)
            mgr.save(10, p1, o1)
            mgr.wait()
            # rewind happened; the job replays and re-saves step 10 with
            # different (newer) state
            p2, o2 = tree(2.0)
            mgr.save(10, p2, o2)
            mgr.wait()
            out = mgr.restore(p1, o1)
            assert out is not None and out[2] == 10
            assert_tree_equal(out[0], p2)

    def test_writer_failure_surfaces_at_wait(self, monkeypatch):
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, async_save=True)

            def boom(step, seq, flat, manifest):
                raise OSError("disk full")

            monkeypatch.setattr(mgr, "_write", boom)
            p, o = tree(1.0)
            mgr.save(5, p, o)
            with pytest.raises(RuntimeError, match="checkpoint write"):
                mgr.wait()
            # the error is consumed: the manager is usable again
            mgr.wait()

    def test_restore_mid_flight_never_loads_torn_checkpoint(self):
        """A checkpoint directory missing its payload (writer died after
        the dir appeared) must be skipped, falling back to the last
        complete one — not asserted on or half-loaded."""
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, async_save=False)
            p1, o1 = tree(1.0)
            mgr.save(10, p1, o1)
            # a torn later checkpoint: directory + manifest, no arrays
            torn = os.path.join(d, "ckpt-00000020")
            os.makedirs(torn)
            with open(os.path.join(torn, "manifest.json"), "w") as f:
                f.write("{}")
            assert mgr.latest_step() == 10
            out = mgr.restore(p1, o1)
            assert out is not None and out[2] == 10
            assert_tree_equal(out[0], p1)

    def test_tmp_debris_cleaned_on_init(self):
        with tempfile.TemporaryDirectory() as d:
            os.makedirs(os.path.join(d, ".tmp-5-1"))
            os.makedirs(os.path.join(d, ".old-5-1"))
            CheckpointManager(d)
            assert not any(n.startswith((".tmp", ".old"))
                           for n in os.listdir(d))


class TestTierSelection:
    def test_peer_then_local_then_cold(self):
        with tempfile.TemporaryDirectory() as d:
            mgr = TieredCheckpointManager(d, async_save=False, dp_size=8,
                                          fast_interval_s=0.0)
            p, o = tree(3.0)
            mgr.save(10, p, o)           # durable
            mgr.save_fast(12, p, o)      # peer + local
            out = mgr.restore_any(p, o)
            assert out[2] == 12 and out[3] is CheckpointTier.PEER
            mgr.drop_peer()              # replica holder died
            out = mgr.restore_any(p, o)
            assert out[2] == 12 and out[3] is CheckpointTier.LOCAL
            mgr.drop_local()             # the node died too
            out = mgr.restore_any(p, o)
            assert out[2] == 10 and out[3] is CheckpointTier.COLD

    def test_all_tiers_bit_identical(self):
        """Acceptance criterion: a hot-spare resume from the peer replica
        is bit-identical to a cold restore of the same snapshot step."""
        with tempfile.TemporaryDirectory() as d:
            mgr = TieredCheckpointManager(d, async_save=False, dp_size=4,
                                          fast_interval_s=0.0)
            p, o = tree(7.0)
            mgr.save(20, p, o)
            mgr.save_fast(20, p, o)
            peer = mgr.restore_any(p, o, step=20)
            assert peer[3] is CheckpointTier.PEER
            mgr.drop_peer()
            local = mgr.restore_any(p, o, step=20)
            assert local[3] is CheckpointTier.LOCAL
            mgr.drop_local()
            cold = mgr.restore_any(p, o, step=20)
            assert cold[3] is CheckpointTier.COLD
            for fast in (peer, local):
                assert_tree_equal(fast[0], cold[0])
                assert_tree_equal(fast[1], cold[1])

    def test_peer_replica_is_a_copy(self):
        """Mutating the live buffers after a fast snapshot must not reach
        into the replica (donated/overwritten training state)."""
        with tempfile.TemporaryDirectory() as d:
            mgr = TieredCheckpointManager(d, async_save=False,
                                          fast_interval_s=0.0)
            p, o = tree(1.0)
            mgr.save_fast(3, p, o)
            p["w"][:] = -99.0
            out = mgr.restore_any(tree(1.0)[0], o)
            np.testing.assert_array_equal(out[0]["w"], np.full((4, 3), 1.0))

    def test_replica_partner_metadata(self):
        with tempfile.TemporaryDirectory() as d:
            mgr = TieredCheckpointManager(d, node_id=4, dp_size=8)
            assert mgr.peer_rank == 5
            mgr2 = TieredCheckpointManager(d, node_id=5, dp_size=8)
            assert mgr2.peer_rank == 4


class TestCadence:
    def test_young_daly_tuning_reacts_to_mttf(self):
        with tempfile.TemporaryDirectory() as d:
            mgr = TieredCheckpointManager(d)
            long = mgr.update_mttf(100 * 3600.0)
            short = mgr.update_mttf(0.5 * 3600.0)
            assert short < long
            rm = RecoveryModel()
            assert rm.min_interval_s <= short <= rm.max_interval_s
            # unhealthy extreme clamps at the floor, quiet at the cap
            assert mgr.update_mttf(1.0) == rm.min_interval_s
            assert mgr.update_mttf(1e9) == rm.max_interval_s

    def test_fixed_interval_not_retuned(self):
        with tempfile.TemporaryDirectory() as d:
            mgr = TieredCheckpointManager(d, fast_interval_s=42.0)
            assert mgr.update_mttf(1.0) == 42.0
            assert mgr.fast_interval_s == 42.0

    def test_on_step_honors_interval(self):
        with tempfile.TemporaryDirectory() as d:
            mgr = TieredCheckpointManager(d, fast_interval_s=100.0)
            p, o = tree(1.0)
            assert mgr.on_step(1, p, o, now=0.0)       # first is free
            assert not mgr.on_step(2, p, o, now=50.0)  # not due yet
            assert mgr.on_step(3, p, o, now=150.0)
            assert mgr.snapshots_taken == 2
            assert mgr.peer_step() == 3
