"""Training-substrate tests: optimizer, checkpointing (fault tolerance),
data pipeline, trainer loop with Guard hook, grad accumulation."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models.model import Model
from repro.train import (AdamWConfig, CheckpointManager, DataConfig,
                         SyntheticLM, TrainConfig, Trainer, apply_adamw,
                         init_opt_state, lr_at, make_train_step)


@pytest.fixture(scope="module")
def small():
    cfg = reduced(get_config("qwen3-4b"))
    model = Model(cfg)
    data = SyntheticLM(DataConfig(cfg.vocab_size, 32, 4))
    return cfg, model, data


class TestOptimizer:
    def test_lr_schedule(self):
        cfg = AdamWConfig(peak_lr=1e-3, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
        assert float(lr_at(cfg, 0)) == 0.0
        assert float(lr_at(cfg, 10)) == pytest.approx(1e-3, rel=1e-3)
        assert float(lr_at(cfg, 100)) == pytest.approx(1e-4, rel=1e-3)

    def test_adamw_moves_params_and_clips(self):
        params = {"w": jnp.ones((4, 4)), "b": jnp.zeros((4,))}
        grads = {"w": jnp.full((4, 4), 100.0), "b": jnp.ones((4,))}
        st = init_opt_state(params)
        cfg = AdamWConfig(grad_clip=1.0, warmup_steps=0, total_steps=10)
        p2, st2, m = apply_adamw(params, grads, st, cfg)
        assert float(m["grad_norm"]) > 1.0
        assert not np.allclose(np.asarray(p2["w"]), 1.0)
        assert int(st2["count"]) == 1

    def test_moments_match_param_tree(self):
        params = {"a": jnp.ones((2, 3)), "nested": {"b": jnp.ones(5)}}
        st = init_opt_state(params)
        assert jax.tree.structure(st["mu"]) == jax.tree.structure(params)


class TestCheckpoint:
    def test_roundtrip_and_retention(self, small):
        cfg, model, data = small
        params, _ = model.init_params(jax.random.key(0))
        opt = init_opt_state(params)
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, keep=2, async_save=False)
            for s in (10, 20, 30):
                mgr.save(s, params, opt)
            assert mgr.all_steps() == [20, 30]     # retention
            out = mgr.restore(params, opt)
            assert out is not None
            p2, o2, step = out
            assert step == 30
            for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_atomicity_tmp_never_visible(self, small):
        cfg, model, data = small
        params, _ = model.init_params(jax.random.key(0))
        opt = init_opt_state(params)
        with tempfile.TemporaryDirectory() as d:
            mgr = CheckpointManager(d, async_save=True)
            mgr.save(5, params, opt)
            mgr.wait()
            assert all(not n.startswith(".tmp") for n in os.listdir(d))


class TestTrainer:
    def test_loss_decreases_and_restores(self, small):
        cfg, model, data = small
        with tempfile.TemporaryDirectory() as d:
            tr = Trainer(model, data,
                         TrainConfig(steps=10, ckpt_interval=5,
                                     opt=AdamWConfig(peak_lr=1e-3,
                                                     warmup_steps=2,
                                                     total_steps=10)),
                         ckpt=CheckpointManager(d))
            out = tr.run()
            hist = out["history"]
            assert hist[-1]["loss"] < hist[0]["loss"]
            tr2 = Trainer(model, data, TrainConfig(steps=12),
                          ckpt=CheckpointManager(d))
            assert tr2.restore() == 10

    def test_guard_hook_triggers_restart(self, small):
        cfg, model, data = small
        calls = {"n": 0, "restarted": 0}

        def hook(step, wall, metrics):
            calls["n"] += 1
            if step == 6 and not calls["restarted"]:
                calls["restarted"] += 1
                return True
            return False

        with tempfile.TemporaryDirectory() as d:
            tr = Trainer(model, data,
                         TrainConfig(steps=8, ckpt_interval=4),
                         ckpt=CheckpointManager(d), hook=hook)
            out = tr.run()
            # restarted at 6 -> rewound to 4 -> finished at 8
            assert out["final_step"] == 8
            assert calls["restarted"] == 1
            steps_seen = [h["step"] for h in out["history"]]
            assert steps_seen.count(5) == 2      # replayed after rewind

    @pytest.mark.slow
    def test_grad_accumulation_matches_full_batch(self, small):
        cfg, model, data = small
        params, _ = model.init_params(jax.random.key(1))
        opt = init_opt_state(params)
        batch = data.batch_at(0)
        full = make_train_step(model, AdamWConfig(), microbatch=0)
        accum = make_train_step(model, AdamWConfig(), microbatch=2)
        p1, _, m1 = jax.jit(full)(params, opt, batch)
        p2, _, m2 = jax.jit(accum)(params, opt, batch)
        assert float(m1["loss"]) == pytest.approx(float(m2["loss"]),
                                                  rel=2e-2)
        l1 = jax.tree.leaves(p1)
        l2 = jax.tree.leaves(p2)
        for a, b in zip(l1, l2):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32),
                                       atol=5e-3, rtol=5e-2)
