"""Per-architecture smoke tests: a REDUCED same-family config runs one
forward/train step, a prefill, and a decode step on CPU; asserts output
shapes and no NaNs. The FULL configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, reduced
from repro.models import common as cm
from repro.models.model import Model

# recurrent state-space archs JIT far slower than the attention family on
# CPU; they run in the full lane (and on main pushes), not the fast one
_HEAVY = {"rwkv6_7b", "recurrentgemma_9b"}
ARCH_PARAMS = [pytest.param(a, marks=pytest.mark.slow) if a in _HEAVY
               else a for a in ARCH_NAMES]


def _batch(cfg, B=2, S=16, key=0):
    rng = np.random.RandomState(key)
    b = {
        "tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.randint(0, cfg.vocab_size, (B, S)), jnp.int32),
    }
    if cfg.mrope_sections:
        pos = jnp.arange(S, dtype=jnp.int32)[None].repeat(B, 0)
        b["positions"] = jnp.stack([pos, pos, pos])
        b["patch_embeds"] = jnp.asarray(
            rng.randn(B, min(4, S), cfg.d_model), jnp.bfloat16)
    if cfg.is_encoder_decoder:
        b["enc_frames"] = jnp.asarray(
            rng.randn(B, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_train_step(arch):
    cfg = reduced(get_config(arch))
    model = Model(cfg)
    params, _ = model.init_params(jax.random.key(0))
    batch = _batch(cfg)
    loss, metrics = jax.jit(model.train_loss)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: loss={loss}"
    assert float(loss) > 0

    grads = jax.jit(jax.grad(lambda p, b: model.train_loss(p, b)[0]))(
        params, batch)
    flat = jax.tree.leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(g, np.float32))) for g in flat), \
        f"{arch}: non-finite grads"


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_prefill_and_decode(arch):
    cfg = reduced(get_config(arch))
    model = Model(cfg)
    params, _ = model.init_params(jax.random.key(1))
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    logits, cache = jax.jit(model.prefill)(params, batch)
    Vp = cm.pad_vocab(cfg.vocab_size)
    assert logits.shape == (B, Vp)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert int(cache["pos"]) == S

    # decode from a fresh cache (decode_32k semantics: step vs fixed cache)
    cache0 = model.init_cache(B, 32)
    tok = jnp.zeros((B,), jnp.int32)
    step = jax.jit(model.decode_step)
    logits2, cache1 = step(params, tok, cache0)
    assert logits2.shape == (B, Vp)
    assert np.all(np.isfinite(np.asarray(logits2, np.float32)))
    assert int(cache1["pos"]) == 1
    logits3, cache2 = step(params, tok, cache1)
    assert np.all(np.isfinite(np.asarray(logits3, np.float32)))
    assert int(cache2["pos"]) == 2
