"""Offline-qualification fleet scaling + the sweep accounting bugfixes.

Covers, with regression tests that fail on the pre-fix code:
  - single-node sweep duration: sequential burns cost ``burn * nd``
    (the pre-fix ``burn * nd / max(nd, 1)`` collapsed to ``burn``);
  - degenerate intra-node pairs: no (0, 0) self-probe on single-device
    nodes, no duplicate ring/cross pairs for small ``nd``;
  - buddy retry: a multi-stage failure is only re-tried against a
    DISJOINT buddy, and buddy exhaustion parks the node
    (QUARANTINED + ticket.buddy_exhausted) instead of silently passing
    or condemning it;
  - scheduler capacity: dequeued work starts when the freeing slot's
    occupant actually finished, and drain stamps the caller's step;
plus the batched-vs-scalar golden contract of ``fleet_qualification``
and the ``GuardSession.prequalify_fleet`` phase.
"""
import numpy as np
import pytest

from repro.core import (ErrorSignals, NodeState, QualificationTicket,
                        SweepCampaign, SweepConfig, SweepReference,
                        fleet_qualification, intra_pairs, multi_node_sweep,
                        single_node_sweep)
from repro.guard import EventBus, GuardSession, SweepScheduler, Tier, \
    TraceSink
from repro.simcluster import FaultKind, FaultRates, SimCluster

QUIET = FaultRates(thermal=0, power=0, mem_ecc=0, nic_down=0,
                   nic_degraded=0, host_cpu=0, congestion=0, fail_stop=0,
                   admission_grey_p=0)

CFG = SweepConfig()


class StubBackend:
    """Healthy scalar backend with a configurable device count."""

    def __init__(self, devices=8):
        self._d = devices
        self._ref = SweepReference(device_tflops=100.0, intra_bw_gbps=100.0,
                                   pair_step_time=1.0)

    def device_count(self, node_id):
        return self._d

    def compute_probe(self, node_id, device, seconds):
        return 100.0

    def intra_bw_probe(self, node_id, a, b):
        assert a != b, f"degenerate self-pair probe ({a}, {b})"
        return 100.0

    def multi_node_probe(self, node_ids, steps):
        return np.full(steps, 1.0)

    def reference(self):
        return self._ref


class PairFailBackend(StubBackend):
    """Single-node stage healthy; the 2-node stage fails whenever a
    contaminated buddy is in the group."""

    def __init__(self, bad=(10,), devices=2):
        super().__init__(devices)
        self.bad = set(bad)
        self.groups = []

    def multi_node_probe(self, node_ids, steps):
        self.groups.append(tuple(node_ids))
        return np.full(steps, 2.0 if self.bad & set(node_ids) else 1.0)


class FakeControl:
    def __init__(self):
        self.t = 0.0
        self._next = 500

    def swap_node(self, old, new):
        pass

    def restart_job(self, reason):
        pass

    def provision_node(self):
        self._next += 1
        return self._next

    def error_signals(self, node_id):
        return ErrorSignals()

    def remediate(self, node_id, stage):
        pass

    def now(self):
        return self.t


def manager_with(backend, spares):
    s = GuardSession.from_tier(Tier.ENHANCED, FakeControl(), backend,
                               sweep_cfg=SweepConfig())
    s.register_spares(spares)
    return s.manager


# ------------------------------------------------- duration accounting

class TestSweepDuration:
    def test_enhanced_sweep_costs_sequential_burns(self):
        """8 devices burn SEQUENTIALLY: an enhanced sweep occupies the
        bench for burn*8 (+ pair setup), not for one device's burn —
        the pre-fix `burn * nd / max(nd, 1)` released qualifications
        ~8x early."""
        rep = single_node_sweep(StubBackend(devices=8), 0, CFG,
                                enhanced=True)
        n_pairs = len(intra_pairs(8))
        assert n_pairs == 12
        assert rep.duration_s == pytest.approx(
            CFG.enhanced_burn_seconds * 8 + 30.0 * n_pairs)
        assert rep.duration_s > 8 * CFG.enhanced_burn_seconds  # not 1x burn

    def test_basic_sweep_duration_scales_with_devices(self):
        four = single_node_sweep(StubBackend(devices=4), 0, CFG)
        eight = single_node_sweep(StubBackend(devices=8), 0, CFG)
        assert four.duration_s == pytest.approx(
            CFG.burn_seconds * 4 + 30.0 * len(intra_pairs(4)))
        assert eight.duration_s - 30.0 * len(intra_pairs(8)) == \
            pytest.approx(2 * (four.duration_s - 30.0 * len(intra_pairs(4))))


# ------------------------------------------------- degenerate pairs

class TestIntraPairs:
    def test_single_device_node_has_no_bw_stage(self):
        """nd == 1 used to emit a (0, 0) self-pair probe; now the bw
        stage is skipped entirely (StubBackend asserts a != b)."""
        rep = single_node_sweep(StubBackend(devices=1), 0, CFG)
        assert rep.passed
        assert rep.measurements["bw"] == {}
        assert rep.duration_s == pytest.approx(CFG.burn_seconds)

    def test_two_device_pairs_deduped(self):
        # ring gives (0,1) and (1,0); cross gives (0,1) again
        assert intra_pairs(2) == [(0, 1)]

    def test_no_self_or_duplicate_pairs(self):
        for nd in range(1, 17):
            pairs = intra_pairs(nd)
            assert all(a != b for a, b in pairs), nd
            assert all(a < b for a, b in pairs), nd
            assert len(set(pairs)) == len(pairs), nd
            if nd > 1:   # every device still covered
                covered = {d for p in pairs for d in p}
                assert covered == set(range(nd)), nd


# ------------------------------------------------- buddy retry fix

class TestBuddyExhaustion:
    def test_single_spare_never_retried_against_same_buddy(self):
        """With one (contaminated) spare the pre-fix retry slice wrapped
        back to the SAME buddy and the node was condemned via triage;
        now the ambiguous failure parks it QUARANTINED with
        buddy_exhausted set."""
        backend = PairFailBackend(bad=(10,))
        mgr = manager_with(backend, spares=[10])
        mgr.state[5] = NodeState.QUARANTINED
        ticket = mgr.begin_qualification(5)
        assert backend.groups == [(5, 10)]          # no same-buddy retest
        assert ticket.buddy_exhausted
        assert ticket.outcome == NodeState.QUARANTINED
        assert mgr.complete_qualification(ticket) == NodeState.QUARANTINED
        assert mgr.state[5] == NodeState.QUARANTINED
        assert mgr.stats.nodes_terminated == 0
        assert mgr.stats.nodes_requalified == 0
        assert 5 not in mgr.spares

    def test_no_buddies_does_not_silently_pass(self):
        """With an empty spare pool the pre-fix enhanced qualification
        skipped the multi stage and requalified the node unverified."""
        backend = PairFailBackend(bad=())
        mgr = manager_with(backend, spares=[])
        mgr.state[5] = NodeState.QUARANTINED
        assert mgr.qualify(5) == NodeState.QUARANTINED
        assert backend.groups == []                 # multi never ran
        assert mgr.state[5] == NodeState.QUARANTINED
        assert 5 not in mgr.spares
        assert mgr.begin_qualification(5).buddy_exhausted

    def test_disjoint_retry_still_disambiguates(self):
        backend = PairFailBackend(bad=(10,))
        mgr = manager_with(backend, spares=[10, 11])
        mgr.state[5] = NodeState.QUARANTINED
        assert mgr.qualify(5) == NodeState.HEALTHY_SPARE
        assert backend.groups == [(5, 10), (5, 11)]
        assert 5 in mgr.spares

    def test_parked_node_waits_for_buddy_capacity(self):
        """A buddy-exhausted node is not re-swept every checkpoint scan
        while the spare pool is unchanged — only once it has GROWN (the
        identical ambiguous sweep would burn the bench for the identical
        parked verdict)."""
        backend = PairFailBackend(bad=(10,))
        s = GuardSession.from_tier(Tier.ENHANCED, FakeControl(), backend,
                                   sweep_cfg=SweepConfig())
        s.register_spares([10])
        s.manager.state[5] = NodeState.QUARANTINED
        assert s.scheduler.submit_quarantined(now=0.0) == 1
        s.scheduler.drain(0.0)
        assert s.manager.state[5] == NodeState.QUARANTINED   # parked
        sweeps = s.manager.stats.sweeps_run
        # pool unchanged: the periodic scan skips the parked node
        assert s.scheduler.submit_quarantined(now=10.0) == 0
        assert s.manager.stats.sweeps_run == sweeps
        # pool grows: the node is retried (and the disjoint buddy clears
        # the contaminated-buddy ambiguity)
        s.register_spares([11])
        assert s.scheduler.submit_quarantined(now=20.0) == 1
        s.scheduler.drain(20.0)
        assert s.manager.state[5] == NodeState.HEALTHY_SPARE


# ------------------------------------------------- scheduler capacity

class FakeManager:
    enhanced_sweep = False
    spare_count = 0

    def __init__(self, durations):
        self.durations = durations

    def begin_qualification(self, nid):
        return QualificationTicket(nid, NodeState.HEALTHY_SPARE,
                                   self.durations[nid], 1, [])

    def complete_qualification(self, ticket):
        ticket.applied = True
        return ticket.outcome

    def quarantined(self):
        return []


class TestSchedulerCapacity:
    def _sched(self, durations, concurrency=1):
        bus = EventBus()
        trace = TraceSink()
        bus.attach(trace)
        sched = SweepScheduler(FakeManager(durations), bus,
                               concurrency=concurrency)
        return sched, trace

    def test_dequeued_work_starts_at_slot_finish_time(self):
        """The pre-fix advance started queued work at ``now``: one
        coarse clock tick under-reported bench occupancy and could
        leave finished work unlanded."""
        sched, trace = self._sched({1: 100.0, 2: 50.0})
        sched.submit(1, now=0.0)
        sched.submit(2, now=0.0)
        assert sched.advance(0.0) == []
        assert sched.busy == 1 and sched.backlog == 1
        done = sched.advance(1000.0)        # ONE coarse tick
        assert [t.node_id for t in done] == [1, 2]
        assert sched.busy == 0 and sched.backlog == 0
        starts = trace.of_kind("sweep_start")
        finishes = trace.of_kind("sweep_finish")
        assert [e.t for e in starts] == [0.0, 100.0]    # not 1000.0
        assert [e.t for e in finishes] == [100.0, 150.0]

    def test_enqueue_time_floors_the_start(self):
        sched, trace = self._sched({7: 10.0})
        sched.submit(7, now=500.0)          # quarantined mid-run
        sched.advance(1000.0)
        start = trace.of_kind("sweep_start")[0]
        assert start.t == 500.0             # not slot-free time 0.0

    def test_drain_stamps_step_and_true_finish_times(self):
        """The pre-fix drain published SweepFinished with whatever step
        the last advance saw; now the caller passes the final step and
        events carry the true (possibly beyond-now) finish times."""
        sched, trace = self._sched({3: 40.0, 4: 40.0})
        sched.submit(3, now=0.0)
        sched.submit(4, now=0.0)
        done = sched.drain(5.0, step=77)
        assert len(done) == 2
        finishes = trace.of_kind("sweep_finish")
        assert [e.step for e in finishes] == [77, 77]
        assert [e.t for e in finishes] == [40.0, 80.0]  # serialized slots

    def test_concurrency_slots_run_in_parallel(self):
        sched, trace = self._sched({1: 60.0, 2: 60.0, 3: 60.0},
                                   concurrency=2)
        for nid in (1, 2, 3):
            sched.submit(nid, now=0.0)
        sched.advance(200.0)
        starts = {e.node_id: e.t for e in trace.of_kind("sweep_start")}
        assert starts[1] == 0.0 and starts[2] == 0.0
        assert starts[3] == 60.0            # third waits for a slot


# ------------------------------------------------- batched campaign

def fleet_cluster(n=64, seed=11):
    c = SimCluster(n_active=n, n_spare=8, reserve=0, rates=QUIET, seed=seed)
    c.injector.inject(FaultKind.POWER, 5, severity=0.8, device=3)
    c.injector.inject(FaultKind.MEM_ECC, 17, severity=0.85, device=1)
    c.injector.inject(FaultKind.NIC_DEGRADED, 29, severity=0.7, device=2)
    c.injector.inject(FaultKind.THERMAL, 41, severity=0.9, device=0)
    c.fleet.advance_thermals(7200.0)
    return c


def fleet_campaign(c, **kw):
    kw.setdefault("reference_pool", tuple(c.spares))
    return SweepCampaign(node_ids=tuple(range(len(c.active))), **kw)


class ScalarOnly:
    """Hides the batched protocol: forces the scalar-compat fallback."""

    def __init__(self, b):
        self._b = b

    def device_count(self, n):
        return self._b.device_count(n)

    def compute_probe(self, n, d, s):
        return self._b.compute_probe(n, d, s)

    def intra_bw_probe(self, n, a, b):
        return self._b.intra_bw_probe(n, a, b)

    def multi_node_probe(self, ids, steps):
        return self._b.multi_node_probe(ids, steps)

    def reference(self):
        return self._b.reference()


def assert_reports_identical(a, b):
    assert len(a) == len(b)
    for ra, rb in zip(a, b):
        assert ra.node_id == rb.node_id
        assert ra.passed == rb.passed, (ra.node_id, ra.failures,
                                        rb.failures)
        assert ra.failures == rb.failures
        assert ra.duration_s == rb.duration_s
        assert set(ra.measurements) == set(rb.measurements)
        for k, va in ra.measurements.items():
            vb = rb.measurements[k]
            if isinstance(va, np.ndarray):
                np.testing.assert_array_equal(va, vb)
            elif isinstance(va, dict):
                assert set(va) == set(vb)
                for p in va:
                    assert va[p] == vb[p], (ra.node_id, k, p)
            else:
                assert va == vb


class TestFleetQualificationGolden:
    def test_batched_equals_scalar_fallback(self):
        """Identical RNG-seeded fleets, one batched pass vs the scalar
        fallback: verdicts, failure strings, durations and raw
        measurements must be bit-identical."""
        cb, cs = fleet_cluster(), fleet_cluster()
        batched = fleet_qualification(cb, fleet_campaign(cb))
        scalar = fleet_qualification(ScalarOnly(cs), fleet_campaign(cs))
        assert_reports_identical(batched.reports, scalar.reports)
        assert batched.reference == scalar.reference
        assert batched.buddies == scalar.buddies
        assert batched.retry_buddies == scalar.retry_buddies
        assert batched.sweeps == scalar.sweeps

    def test_campaign_matches_per_node_scalar_sweeps(self):
        """Each campaign report decomposes into the exact scalar
        single_node_sweep / multi_node_sweep calls with the recorded
        reference and buddy assignment — including the fixed duration
        math."""
        c = fleet_cluster()
        res = fleet_qualification(c, fleet_campaign(c))
        c2 = fleet_cluster()
        for rep in res.reports:
            n = rep.node_id
            s = single_node_sweep(c2, n, CFG, enhanced=True,
                                  reference=res.reference)
            np.testing.assert_array_equal(rep.measurements["tflops"],
                                          s.measurements["tflops"])
            assert rep.measurements["bw"] == s.measurements["bw"]
            expected_dur = s.duration_s
            expected_failures = list(s.failures)
            if s.passed and res.buddies.get(n):
                m = multi_node_sweep(c2, n, res.buddies[n], CFG,
                                     reference=res.reference)
                expected_dur += m.duration_s
                if not m.passed and res.retry_buddies.get(n):
                    m = multi_node_sweep(c2, n, res.retry_buddies[n], CFG,
                                         reference=res.reference)
                    expected_dur += m.duration_s
                expected_failures += m.failures
                np.testing.assert_array_equal(
                    rep.measurements["step_times"],
                    m.measurements["step_times"])
            assert rep.duration_s == expected_dur
            assert rep.failures == expected_failures

    def test_campaign_detects_all_fault_classes(self):
        c = fleet_cluster()
        res = fleet_qualification(c, fleet_campaign(c))
        assert set(res.failed) == {5, 17, 29, 41}
        assert res.calibrated
        # calibrated reference sits at the (healthy-majority) medians
        assert res.reference.device_tflops == pytest.approx(
            c.fleet.hw.base_tflops, rel=0.05)
        # 8-device enhanced sweeps: the campaign's bench time reflects
        # sequential burns (the duration fix at fleet scale)
        healthy = next(r for r in res.reports if r.passed)
        assert healthy.duration_s > 8 * CFG.enhanced_burn_seconds

    def test_heterogeneous_fleet_rejected_loudly(self):
        class Hetero(StubBackend):
            def device_count(self, node_id):
                return 8 if node_id == 0 else 4

        with pytest.raises(ValueError, match="uniform device count"):
            fleet_qualification(Hetero(), SweepCampaign(node_ids=(0, 1)))

    def test_bootstrap_pool_with_disjoint_retry(self):
        """No reference pool: buddies bootstrap from single-stage
        passers, so a comm-degraded suspect can land in a healthy
        node's group — the disjoint-buddy retry must clear the healthy
        node and still fail the suspect."""
        c = fleet_cluster()
        res = fleet_qualification(c, fleet_campaign(c, reference_pool=()))
        for nid, bs in res.buddies.items():
            assert nid not in bs
        for nid, bs in res.retry_buddies.items():
            assert not (set(bs) & set(res.buddies[nid]))
        assert set(res.failed) == {5, 17, 29, 41}


# ------------------------------------------------- session integration

class TestPrequalifyFleet:
    def _session(self, c, tier=Tier.ENHANCED):
        s = GuardSession.from_tier(tier, control=c, sweep_backend=c)
        s.register_active(c.active)
        s.register_spares(c.spares)
        return s

    def test_failures_quarantined_and_replaced(self):
        c = SimCluster(n_active=16, n_spare=4, reserve=0, rates=QUIET,
                       seed=5)
        c.injector.inject(FaultKind.POWER, 3, severity=0.8, device=2)
        c.injector.inject(FaultKind.NIC_DEGRADED, 7, severity=0.7,
                          device=1)
        s = self._session(c)
        res = s.prequalify_fleet()
        assert set(res.failed) == {3, 7}
        for nid in (3, 7):
            assert s.manager.state[nid] == NodeState.QUARANTINED
            assert nid not in c.active
        # failures are routed into the event-driven per-node loop
        assert s.scheduler.busy + s.scheduler.backlog == 2
        camp = s.trace.of_kind("campaign_finish")
        assert len(camp) == 1
        assert camp[0].nodes == 16 and camp[0].passed == 14
        assert set(camp[0].failed) == {3, 7}
        assert camp[0].calibrated
        swaps = s.trace.of_kind("swap")
        assert {e.old for e in swaps} == {3, 7}
        for e in swaps:
            assert e.new in c.active

    def test_clean_fleet_passes_untouched(self):
        c = SimCluster(n_active=12, n_spare=2, reserve=0, rates=QUIET,
                       seed=9)
        s = self._session(c)
        active_before = list(c.active)
        res = s.prequalify_fleet()
        assert res.failed == []
        assert c.active == active_before
        assert s.scheduler.busy + s.scheduler.backlog == 0

    def test_requires_sweep_tooling(self):
        c = SimCluster(n_active=8, n_spare=2, reserve=0, rates=QUIET,
                       seed=1)
        s = self._session(c, tier=Tier.BURNIN)
        with pytest.raises(RuntimeError):
            s.prequalify_fleet()
