"""Elastic scaling: checkpoints are topology-independent — a job saved
under one mesh restores and continues under another (or none)."""
import tempfile

import jax
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.dist import api as dist
from repro.launch.mesh import make_cpu_mesh
from repro.models.model import Model
from repro.train import (AdamWConfig, CheckpointManager, DataConfig,
                         SyntheticLM, TrainConfig, Trainer)


def _mk_trainer(model, data, d, steps):
    return Trainer(model, data,
                   TrainConfig(steps=steps, ckpt_interval=3,
                               opt=AdamWConfig(peak_lr=1e-3, warmup_steps=1,
                                               total_steps=steps)),
                   ckpt=CheckpointManager(d, async_save=False))


@pytest.mark.slow
def test_restore_across_topologies():
    cfg = reduced(get_config("qwen3-4b"))
    model = Model(cfg)
    data = SyntheticLM(DataConfig(cfg.vocab_size, 32, 4))
    with tempfile.TemporaryDirectory() as d:
        # phase 1: train 6 steps on a (1,1) mesh (sharded code path)
        mesh = make_cpu_mesh()
        with mesh, dist.use_mesh(mesh):
            tr1 = _mk_trainer(model, data, d, steps=6)
            out1 = tr1.run()
        assert out1["final_step"] == 6

        # phase 2: two independent restores WITHOUT a mesh (different
        # topology) — restored states must agree bit-exactly
        tr2 = _mk_trainer(model, data, d, steps=9)
        tr3 = _mk_trainer(model, data, d, steps=9)
        assert tr2.restore() == 6
        assert tr3.restore() == 6
        for a, b in zip(jax.tree.leaves(tr2.params),
                        jax.tree.leaves(tr3.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

        # ...and training continues to completion on the new topology
        out2 = tr2.run()
        assert out2["final_step"] == 9
        losses2 = [h["loss"] for h in out2["history"]]
        assert all(np.isfinite(losses2))
