"""Simulated-fleet behaviour tests: step composition, fault effects,
reroute accounting, escalation, and the end-to-end closed loop."""
import numpy as np
import pytest

from repro.core import (DetectorConfig, HealthManager, NodeState,
                        OnlineMonitor, PolicyConfig)
from repro.simcluster import (FaultKind, FaultRates, RunConfig, SimCluster,
                              Tier, freq_at_temp, simulate_run)

QUIET = FaultRates(thermal=0, power=0, mem_ecc=0, nic_down=0,
                   nic_degraded=0, host_cpu=0, congestion=0, fail_stop=0,
                   admission_grey_p=0)


def cluster(**kw):
    kw.setdefault("rates", QUIET)
    kw.setdefault("n_active", 16)
    kw.setdefault("n_spare", 4)
    return SimCluster(**kw)


class TestStepComposition:
    def test_healthy_step_time(self):
        c = cluster()
        w = c.workload
        times = [c.run_step()["step_time"] for _ in range(20)]
        assert abs(np.mean(times) / w.healthy_step_s - 1) < 0.05

    def test_single_slow_node_gates_job(self):
        c = cluster()
        c.injector.inject(FaultKind.POWER, 5, severity=0.9)
        t = c.node_barrier_times()
        assert np.argmax(t) == 5
        assert c.run_step()["step_time"] == pytest.approx(t.max(), rel=0.2)

    def test_thermal_ramps_over_time(self):
        c = cluster()
        c.injector.inject(FaultKind.THERMAL, 3, severity=0.9, device=0)
        first = c.node_barrier_times()[3]
        for _ in range(200):
            c.run_step()
        later = c.node_barrier_times()[3]
        assert later > first * 1.1

    def test_throttle_curve_monotone(self):
        temps = np.linspace(40, 95, 50)
        freqs = freq_at_temp(temps)
        assert np.all(np.diff(freqs) <= 1e-12)

    def test_reroute_traffic_accounting(self):
        c = cluster()
        c.injector.inject(FaultKind.NIC_DOWN, 2, device=6)
        c.fleet.nic_tx_bytes[:] = 0
        for _ in range(10):
            c.run_step()
        tx = c.fleet.nic_tx_bytes[2]
        assert tx[6] == 0.0
        assert tx[0] == pytest.approx(2 * tx[1])

    def test_failstop_crashes_job(self):
        c = cluster()
        c.injector.inject(FaultKind.FAIL_STOP, 4, severity=1.0)
        rec = c.run_step()
        assert rec["crashed"]
        assert c.crashed_nodes() == [4]

    def test_escalation_turns_grey_into_failstop(self):
        c = cluster(rates=FaultRates(
            thermal=0, power=0, mem_ecc=0, nic_down=0, nic_degraded=0,
            host_cpu=0, congestion=0, fail_stop=0,
            escalation_mean_s=1.0, admission_grey_p=0))
        f = c.injector.inject(FaultKind.POWER, 1, severity=0.5)
        assert f.escalate_at is not None
        c.advance_idle(3600.0)
        c.injector.tick(c.t, 60.0, np.asarray(c.active))
        assert not c.fleet.alive[1]

    def test_congestion_expires(self):
        c = cluster()
        f = c.injector.inject(FaultKind.CONGESTION, 0, severity=1.0)
        c.injector.tick(c.t, 1.0, np.asarray(c.active))
        assert c.injector.congestion_factor[0] > 1.5
        c.advance_idle(f.t_end + 1.0)
        assert c.injector.congestion_factor[0] == 1.0


class TestClosedLoop:
    def test_manager_swaps_severe_straggler(self):
        c = cluster(n_active=16, n_spare=4, seed=11)
        mon = OnlineMonitor(DetectorConfig(), PolicyConfig())
        mgr = HealthManager(c, c, mon, enhanced_sweep=True)
        for nid in c.active:
            mgr.register(nid, NodeState.ACTIVE)
        for nid in c.spares:
            mgr.register(nid, NodeState.HEALTHY_SPARE)
        c.injector.inject(FaultKind.POWER, 7, severity=0.95)

        swapped = False
        for step in range(400):
            c.run_step()
            if step % c.window_steps == 0:
                frame = c.collect()
                if frame is None:
                    continue
                for ev in mon.observe(frame):
                    mgr.handle(ev)
            if step and step % 60 == 0:     # checkpoint boundary
                mgr.on_checkpoint()
            if 7 not in c.active:
                swapped = True
                break
        assert swapped
        assert mgr.state[7] == NodeState.QUARANTINED
        # offline qualification: power fault fails the sweep -> triage
        # (gpu path) -> eventually terminated or requalified
        final = mgr.qualify(7)
        assert final in (NodeState.TERMINATED, NodeState.HEALTHY_SPARE)

    def test_requalified_node_returns_to_pool(self):
        c = cluster(n_active=8, n_spare=2, seed=12)
        mon = OnlineMonitor()
        mgr = HealthManager(c, c, mon, enhanced_sweep=True)
        for nid in c.active:
            mgr.register(nid, NodeState.ACTIVE)
        for nid in c.spares:
            mgr.register(nid, NodeState.HEALTHY_SPARE)
        # healthy node wrongly quarantined (a false positive)
        mgr.state[3] = NodeState.QUARANTINED
        assert mgr.qualify(3) == NodeState.HEALTHY_SPARE
        assert 3 in mgr.spares


class TestRuntime:
    @pytest.mark.parametrize("tier", [Tier.BURNIN, Tier.ENHANCED])
    def test_short_run_completes(self, tier):
        r = simulate_run(RunConfig(tier=tier, n_nodes=24, n_spare=4,
                                   duration_h=4.0, seed=5))
        assert r.steps > 0
        assert r.elapsed_h >= 4.0
        assert 0 < r.mfu < 0.25
        assert np.isfinite(r.mttf_h)

    def test_guard_improves_over_burnin(self):
        """The paper's headline directionally: enhanced >= burnin on MFU."""
        mfu = {}
        for tier in (Tier.BURNIN, Tier.ENHANCED):
            rs = [simulate_run(RunConfig(
                tier=tier, n_nodes=48, n_spare=8, duration_h=12.0,
                initial_grey_p=0.2, seed=s)) for s in (0, 1)]
            mfu[tier] = np.mean([r.mfu for r in rs])
        assert mfu[Tier.ENHANCED] > mfu[Tier.BURNIN]
