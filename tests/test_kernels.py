"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret=True executes the Pallas bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import attention, attention_ref
from repro.kernels.fleet_score import (median_lastdim_ref, score_rows,
                                       score_rows_ref)
from repro.kernels.sweep_burn import burn, burn_flops, burn_ref
from repro.kernels.wkv6 import wkv6, wkv6_naive

rng = np.random.RandomState(7)


def to_khw(x):
    return jnp.moveaxis(x, 1, 2)


class TestFlashAttention:
    @pytest.mark.parametrize("B,S,T,Hq,Hkv,hd,causal", [
        (2, 128, 128, 4, 2, 64, True),
        (1, 256, 256, 8, 8, 128, True),
        (2, 96, 96, 4, 1, 64, False),       # padding + MQA
        (1, 300, 300, 2, 2, 32, True),      # non-multiple lengths
        (2, 64, 192, 4, 2, 64, False),      # cross-shaped T != S
    ])
    def test_matches_oracle(self, B, S, T, Hq, Hkv, hd, causal):
        q = jnp.asarray(rng.randn(B, S, Hq, hd), jnp.float32)
        k = jnp.asarray(rng.randn(B, T, Hkv, hd), jnp.float32)
        v = jnp.asarray(rng.randn(B, T, Hkv, hd), jnp.float32)
        out = attention(q, k, v, causal=causal, block_q=64, block_k=64)
        ref = jnp.moveaxis(
            attention_ref(to_khw(q), to_khw(k), to_khw(v), causal=causal),
            1, 2)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5),
                                            (jnp.bfloat16, 3e-2)])
    def test_dtypes(self, dtype, atol):
        q = jnp.asarray(rng.randn(1, 128, 4, 64), dtype)
        k = jnp.asarray(rng.randn(1, 128, 2, 64), dtype)
        v = jnp.asarray(rng.randn(1, 128, 2, 64), dtype)
        out = attention(q, k, v, block_q=64, block_k=64)
        ref = jnp.moveaxis(attention_ref(to_khw(q), to_khw(k), to_khw(v)),
                           1, 2)
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(ref, np.float32),
            atol=atol, rtol=atol)

    def test_grad_finite(self):
        q = jnp.asarray(rng.randn(1, 64, 2, 32), jnp.float32)
        k = jnp.asarray(rng.randn(1, 64, 2, 32), jnp.float32)
        v = jnp.asarray(rng.randn(1, 64, 2, 32), jnp.float32)
        g = jax.grad(lambda q: attention(q, k, v, block_q=32,
                                         block_k=32).sum())(q)
        assert np.all(np.isfinite(np.asarray(g)))

    def test_block_shape_invariance(self):
        q = jnp.asarray(rng.randn(1, 256, 2, 64), jnp.float32)
        k = jnp.asarray(rng.randn(1, 256, 2, 64), jnp.float32)
        v = jnp.asarray(rng.randn(1, 256, 2, 64), jnp.float32)
        a = attention(q, k, v, block_q=64, block_k=64)
        b = attention(q, k, v, block_q=128, block_k=256)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


class TestWKV6:
    def _inputs(self, B, S, H, hd):
        mk = lambda *s: jnp.asarray(rng.randn(*s) * 0.5, jnp.float32)
        r, k, v = mk(B, S, H, hd), mk(B, S, H, hd), mk(B, S, H, hd)
        logw = -jnp.exp(jnp.asarray(rng.randn(B, S, H, hd) * 0.5 - 2.0,
                                    jnp.float32))
        u = mk(H, hd) * 0.3
        s0 = mk(B, H, hd, hd) * 0.1
        return r, k, v, logw, u, s0

    @pytest.mark.parametrize("B,S,H,hd,chunk", [
        (2, 128, 2, 64, 32),
        (1, 64, 4, 32, 64),
        (2, 96, 1, 16, 32),
        (1, 256, 2, 128, 64),
    ])
    def test_matches_both_oracles(self, B, S, H, hd, chunk):
        r, k, v, logw, u, s0 = self._inputs(B, S, H, hd)
        y, s = wkv6(r, k, v, logw, u, s0, chunk=chunk)
        yn, sn = wkv6_naive(to_khw(r), to_khw(k), to_khw(v), to_khw(logw),
                            u, s0)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(jnp.moveaxis(yn, 1, 2)),
                                   atol=2e-3, rtol=1e-3)
        np.testing.assert_allclose(np.asarray(s), np.asarray(sn),
                                   atol=2e-3, rtol=1e-3)

    def test_chunk_invariance(self):
        r, k, v, logw, u, s0 = self._inputs(1, 128, 2, 32)
        y32, s32 = wkv6(r, k, v, logw, u, s0, chunk=32)
        y64, s64 = wkv6(r, k, v, logw, u, s0, chunk=64)
        np.testing.assert_allclose(np.asarray(y32), np.asarray(y64),
                                   atol=1e-4)
        np.testing.assert_allclose(np.asarray(s32), np.asarray(s64),
                                   atol=1e-4)

    def test_state_carries_across_calls(self):
        """Processing 2*S tokens == two chained S-token calls."""
        r, k, v, logw, u, s0 = self._inputs(1, 128, 2, 32)
        y_full, s_full = wkv6(r, k, v, logw, u, s0, chunk=32)
        h = 64
        y1, s1 = wkv6(r[:, :h], k[:, :h], v[:, :h], logw[:, :h], u, s0,
                      chunk=32)
        y2, s2 = wkv6(r[:, h:], k[:, h:], v[:, h:], logw[:, h:], u, s1,
                      chunk=32)
        np.testing.assert_allclose(np.asarray(y_full[:, h:]),
                                   np.asarray(y2), atol=1e-3)
        np.testing.assert_allclose(np.asarray(s_full), np.asarray(s2),
                                   atol=1e-3)


class TestSweepBurn:
    @pytest.mark.parametrize("M,K,iters", [(128, 128, 16), (256, 256, 8),
                                           (512, 512, 16)])
    def test_matches_oracle(self, M, K, iters):
        a = jnp.asarray(rng.randn(M, K), jnp.float32)
        b = jnp.asarray(rng.randn(K, K), jnp.float32)
        out = burn(a, b, iters=iters, iters_per_block=8)
        ref = burn_ref(a, b, iters=iters)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-3)

    def test_flops_accounting(self):
        assert burn_flops(512, 512, 64) == 2 * 512**3 * 64

    def test_checksum_is_deterministic(self):
        a = jnp.asarray(rng.randn(128, 128), jnp.float32)
        b = jnp.asarray(rng.randn(128, 128), jnp.float32)
        o1 = burn(a, b, iters=16, iters_per_block=8)
        o2 = burn(a, b, iters=16, iters_per_block=8)
        assert np.array_equal(np.asarray(o1), np.asarray(o2))


class TestFleetScore:
    """Golden parity for repro.kernels.fleet_score: the jax and pallas
    backends must agree with the NumPy oracle (``score_rows_ref``)
    bit-for-bit on verdict masks — the detector's scalar-vs-batched
    contract rides on it."""

    def _mats(self, R, M, N):
        # tight healthy baseline (1.0-1.1) so the planted slowdowns are
        # unambiguous under the robust-z threshold for any seed draw
        mats = (rng.rand(R, M, N).astype(np.float32) * 0.1 + 1.0)
        mats[:, 0, N - 1] *= 1.5          # planted step-time straggler
        mats[:, 0, 3] *= 1.3
        return mats

    @pytest.mark.parametrize("n", [5, 8, 64, 129])
    def test_median_ref_matches_numpy(self, n):
        x = rng.rand(4, 3, n).astype(np.float32)
        got = median_lastdim_ref(x)
        want = np.median(x, axis=-1, keepdims=True).astype(np.float32)
        np.testing.assert_array_equal(got, want)

    @pytest.mark.parametrize("backend", ["jax", "pallas"])
    @pytest.mark.parametrize("R,M,N", [(3, 4, 64), (2, 3, 130)])
    def test_backends_match_ref(self, backend, R, M, N):
        mats = self._mats(R, M, N)
        dirs = [1.0, 1.0, -1.0, 1.0][:M]
        dev_r, rel_r, con_r = score_rows_ref(mats, dirs, 0)
        dev_b, rel_b, con_b = score_rows(mats, dirs, 0, backend=backend)
        np.testing.assert_array_equal(dev_r, dev_b)     # bit-identical
        np.testing.assert_allclose(rel_r, rel_b, rtol=0, atol=0)
        np.testing.assert_allclose(con_r, con_b, rtol=0, atol=0)

    def test_planted_straggler_flagged(self):
        mats = self._mats(2, 3, 32)
        dev, rel, contrib = score_rows_ref(mats, [1.0, 1.0, 1.0], 0)
        assert dev[:, 0, 31].all()
        assert (contrib[:, 31] > 0).all()
        assert contrib.shape == rel.shape == (2, 32)

    def test_numpy_backend_is_the_ref(self):
        mats = self._mats(2, 2, 16)
        a = score_rows(mats, [1.0, 1.0], 0, backend="numpy")
        b = score_rows_ref(mats, [1.0, 1.0], 0)
        for x, y in zip(a, b):
            np.testing.assert_array_equal(x, y)
