"""Golden equivalence: the vectorized FleetAssessment detector must
match the pre-refactor per-node reference implementation — flags,
stall/step-deviant verdicts, support sets and latch state bit-exactly,
slowdown tolerance-pinned (float32 pipeline vs the reference's float64
accumulation) — over recorded frame sequences that exercise warmup,
node replacement backfill, fleet resize and hysteresis. A second sweep
pins the pallas fleet-score kernel bit-identical to the numpy scorer
over the same sequences."""
import copy
import dataclasses
from collections import deque

import numpy as np
import pytest

from repro.core import DetectorConfig, StragglerDetector
from repro.core.detector import FleetAssessment, robust_z
from repro.core.telemetry import HARDWARE_METRICS, METRIC_DIRECTION, Frame
from repro.simcluster import FaultKind, FaultRates, SimCluster


# --------------------------------------------------------------- reference
# Frozen port of the per-node detector as it existed before the
# vectorization refactor (list-stacked history + per-id dict latches).


class _RefRing:
    def __init__(self, depth):
        self.depth = depth
        self._frames = deque(maxlen=depth)

    def push(self, frame):
        if self._frames:
            last_ids = self._frames[-1].node_ids
            if len(frame.node_ids) != len(last_ids):
                self._frames.clear()
            elif not np.array_equal(frame.node_ids, last_ids):
                changed = frame.node_ids != last_ids
                for f in self._frames:
                    for m, vals in f.metrics.items():
                        if m in frame.metrics:
                            vals[changed] = frame.metrics[m][changed]
                    f.valid[changed] = True
                    f.node_ids = f.node_ids.copy()
                    f.node_ids[changed] = frame.node_ids[changed]
        self._frames.append(frame)

    def __len__(self):
        return len(self._frames)

    def stacked(self, metric):
        return np.stack([f.metrics[metric] for f in self._frames])

    def last(self):
        return self._frames[-1]


class RefDetector:
    def __init__(self, cfg=None):
        self.cfg = cfg or DetectorConfig()
        self.history = _RefRing(self.cfg.window)
        self._clean_streak = {}
        self._latched = {}

    def _deviation_matrix(self, metric):
        cfg = self.cfg
        hist = self.history.stacked(metric)
        direction = METRIC_DIRECTION[metric]
        med = np.median(hist, axis=1, keepdims=True)
        floor = np.maximum(np.abs(med) * cfg.mad_floor_frac, 1e-9)
        z = robust_z(hist, axis=1, mad_floor=floor) * direction
        return z > cfg.z_threshold

    def update(self, frame):
        cfg = self.cfg
        self.history.push(frame)
        n = len(frame.node_ids)
        depth = len(self.history)
        warmed = depth >= cfg.persistence
        need = cfg.persistence if warmed else depth + 1

        st_hist = self.history.stacked("step_time")
        med = np.median(st_hist, axis=1, keepdims=True)
        rel = st_hist / np.maximum(med, 1e-9) - 1.0
        step_dev_w = self._deviation_matrix("step_time") & \
            (rel > cfg.slowdown_floor)
        dev_count = step_dev_w.sum(0)
        step_deviant = dev_count >= need
        slow_sum = np.where(step_dev_w, rel, 0.0).sum(0)
        slowdown = np.where(step_deviant,
                            slow_sum / np.maximum(dev_count, 1), 0.0)

        last = self.history.last()
        stalled = (~last.valid) | (
            last.metrics["step_time"] >
            cfg.stall_factor * np.median(last.metrics["step_time"]))

        support_masks = {}
        for m in HARDWARE_METRICS:
            if m in last.metrics:
                dev = self._deviation_matrix(m)
                support_masks[m] = dev.sum(0) >= need

        support_count = np.zeros(n, dtype=int)
        for mask in support_masks.values():
            support_count += mask.astype(int)

        raw_flag = stalled | step_deviant | (support_count >= cfg.min_support)

        out = []
        for i, nid in enumerate(frame.node_ids):
            nid = int(nid)
            latched = self._latched.get(nid, False)
            if raw_flag[i]:
                self._clean_streak[nid] = 0
                latched = True
            elif latched:
                streak = self._clean_streak.get(nid, 0) + 1
                self._clean_streak[nid] = streak
                if streak >= cfg.clear_windows:
                    latched = False
            self._latched[nid] = latched
            out.append(dict(
                node_id=nid,
                slowdown=float(slowdown[i]),
                stalled=bool(stalled[i]),
                support=[m for m, msk in support_masks.items() if msk[i]],
                step_deviant=bool(step_deviant[i]),
                flagged=latched))
        return out

    def is_latched(self, node_id):
        return self._latched.get(node_id, False)

    def reset_node(self, node_id):
        self._latched.pop(node_id, None)
        self._clean_streak.pop(node_id, None)


# ----------------------------------------------------------- frame sources


def full_frame(step, step_times, n=None, **hw):
    n = n or len(step_times)
    metrics = {
        "step_time": np.asarray(step_times, float),
        "gpu_temp": np.asarray(hw.get("temps", np.full(n, 58.0)), float),
        "gpu_util": np.full(n, 0.97),
        "gpu_freq": np.asarray(hw.get("freqs", np.full(n, 1.93)), float),
        "gpu_power": np.full(n, 350.0),
        "nic_errors": np.asarray(hw.get("nic_err", np.zeros(n)), float),
        "nic_tx_rate": np.full(n, 50.0),
        "nic_up": np.ones(n),
    }
    ids = hw.get("node_ids", np.arange(n, dtype=np.int64))
    valid = hw.get("valid", np.ones(n, bool))
    return Frame(t=step * 60.0, step=step, node_ids=ids,
                 metrics=metrics, valid=valid)


def scripted_sequence():
    """Warmup -> sustained straggler -> replacement backfill -> stall ->
    heartbeat loss -> hw-only deviant -> recovery -> fleet resize."""
    rng = np.random.RandomState(7)
    frames = []
    step = 0

    def noise(n=16):
        return 10.0 * (1 + rng.normal(0, 0.003, n))

    for _ in range(4):                       # warmup, healthy
        frames.append(full_frame(step, noise())); step += 1
    for _ in range(6):                       # node 5 sustained +18%
        t = noise(); t[5] *= 1.18
        frames.append(full_frame(step, t)); step += 1
    # node 5 replaced by node 99: backfill must protect the newcomer
    ids = np.arange(16, dtype=np.int64); ids[5] = 99
    for _ in range(4):
        frames.append(full_frame(step, noise(), node_ids=ids.copy()))
        step += 1
    # node 3 stalls hard for one window, then recovers
    t = noise(); t[3] *= 30.0
    frames.append(full_frame(step, t, node_ids=ids.copy())); step += 1
    for _ in range(3):
        frames.append(full_frame(step, noise(), node_ids=ids.copy()))
        step += 1
    # node 7 loses heartbeat once
    v = np.ones(16, bool); v[7] = False
    frames.append(full_frame(step, noise(), node_ids=ids.copy(), valid=v))
    step += 1
    # node 11: two hardware signals deviate, no step impact
    for _ in range(6):
        temps = np.full(16, 58.0); temps[11] = 88.0
        freqs = np.full(16, 1.93); freqs[11] = 1.2
        frames.append(full_frame(step, noise(), node_ids=ids.copy(),
                                 temps=temps, freqs=freqs))
        step += 1
    for _ in range(6):                       # recovery / hysteresis clears
        frames.append(full_frame(step, noise(), node_ids=ids.copy()))
        step += 1
    # fleet resize: history restarts
    for _ in range(5):
        frames.append(full_frame(step, noise(12), n=12)); step += 1
    return frames


def simulated_sequence():
    """Frames recorded off the simulated fleet under real fault churn."""
    rates = FaultRates(congestion=0.2, fail_stop=0, admission_grey_p=0)
    c = SimCluster(24, 4, rates=rates, seed=21)
    c.injector.inject(FaultKind.POWER, 7, severity=0.9)
    c.injector.inject(FaultKind.THERMAL, 11, severity=0.8)
    c.fleet.advance_thermals(3600.0)
    frames = []
    for w in range(30):
        c.run_window(6)
        if w == 12:                          # mid-sequence replacement
            c.swap_node(7, c.spares[0])
        f = c.collect()
        if f is not None:
            frames.append(f)
    return frames


# ----------------------------------------------------------------- tests


def assert_equivalent(frames, cfg=None, resets=()):
    new = StragglerDetector(cfg)
    ref = RefDetector(cfg)
    resets = dict(resets)
    for w, frame in enumerate(frames):
        fa = new.update(copy.deepcopy(frame))
        rs = ref.update(copy.deepcopy(frame))
        assert isinstance(fa, FleetAssessment)
        for i, r in enumerate(rs):
            a = fa.node(i)
            assert a.node_id == r["node_id"], (w, i)
            assert a.flagged == r["flagged"], (w, i)
            assert a.stalled == r["stalled"], (w, i)
            assert a.step_deviant == r["step_deviant"], (w, i)
            # verdict booleans above are exact; slowdown is the one
            # continuous output, now float32 end-to-end against the
            # reference's float64 accumulation — tolerance, not bits
            assert a.slowdown == pytest.approx(r["slowdown"],
                                               rel=1e-5, abs=1e-7), (w, i)
            assert a.support == r["support"], (w, i)
        # latch state agrees for every id either side has ever seen
        seen = set(ref._latched) | {int(n) for n in frame.node_ids}
        for nid in seen:
            assert new.is_latched(nid) == ref.is_latched(nid), (w, nid)
        if w in resets:
            new.reset_node(resets[w])
            ref.reset_node(resets[w])


class TestGoldenEquivalence:
    def test_scripted_sequence(self):
        assert_equivalent(scripted_sequence())

    def test_scripted_sequence_strict_config(self):
        assert_equivalent(scripted_sequence(),
                          DetectorConfig(persistence=2, clear_windows=2,
                                         z_threshold=2.5))

    def test_simulated_sequence(self):
        assert_equivalent(simulated_sequence())

    def test_simulated_sequence_with_reset(self):
        # reset_node mid-stream (what monitor.node_replaced does)
        assert_equivalent(simulated_sequence(), resets={13: 7})

    def test_lazy_materialization_budget(self):
        """The equivalence above materializes every node; the production
        path must stay O(flagged): a straggler-free fleet materializes
        nothing, a one-straggler fleet exactly one per window."""
        det = StragglerDetector()
        rng = np.random.RandomState(0)
        for w in range(10):
            t = 10 + rng.normal(0, 0.01, 256)
            t[17] = 12.5
            fa = det.update(full_frame(w, t, n=256))
            fa.flagged_assessments()
            # persistence=3: the straggler latches from the 3rd window on
            assert fa.materialized == (1 if w >= 2 else 0)


def nan_sensor_sequence():
    """Healthy fleet whose hardware sensors intermittently drop out
    (NaN rows): the scorers must agree on how missing telemetry
    propagates through median/MAD and the support masks."""
    rng = np.random.RandomState(42)
    frames = []
    for step in range(14):
        n = 16
        t = 10.0 * (1 + rng.normal(0, 0.003, n))
        if step >= 4:
            t[9] *= 1.2                       # straggler amid sensor loss
        f = full_frame(step, t)
        if step % 3 == 1:                     # whole-row sensor dropout
            bad = rng.choice(n, 3, replace=False)
            for m in ("gpu_temp", "gpu_power", "nic_tx_rate"):
                f.metrics[m][bad] = np.nan
        if step == 7:                         # one fully-NaN metric
            f.metrics["gpu_freq"][:] = np.nan
        frames.append(f)
    return frames


def assert_scorers_agree(frames, cfg=None, backend="pallas"):
    """Drive numpy- and kernel-backed detectors over identical frames:
    every verdict array must be bit-identical (all backends are f32
    end-to-end; the kernel is a fusion, not a reformulation)."""
    cfg = cfg or DetectorConfig()
    det_np = StragglerDetector(dataclasses.replace(cfg, scorer="numpy"))
    det_pl = StragglerDetector(dataclasses.replace(cfg, scorer=backend))
    for w, frame in enumerate(frames):
        a = det_np.update(copy.deepcopy(frame))
        b = det_pl.update(copy.deepcopy(frame))
        np.testing.assert_array_equal(a.node_ids, b.node_ids)
        np.testing.assert_array_equal(a.flagged, b.flagged,
                                      err_msg=f"flagged w={w}")
        np.testing.assert_array_equal(a.slowdown, b.slowdown,
                                      err_msg=f"slowdown w={w}")
        np.testing.assert_array_equal(a.stalled, b.stalled,
                                      err_msg=f"stalled w={w}")
        np.testing.assert_array_equal(a.step_deviant, b.step_deviant,
                                      err_msg=f"step_deviant w={w}")
        assert a.support_masks.keys() == b.support_masks.keys()
        for m in a.support_masks:
            np.testing.assert_array_equal(a.support_masks[m],
                                          b.support_masks[m],
                                          err_msg=f"support[{m}] w={w}")


class TestPallasGoldenSweep:
    """The pallas fleet-score kernel vs the numpy scorer, bit-identical
    across warmup, replacement backfill, resize (generation bump),
    fault churn and NaN sensor rows."""

    def test_scripted_sequence(self):
        assert_scorers_agree(scripted_sequence())

    def test_scripted_sequence_strict_config(self):
        assert_scorers_agree(scripted_sequence(),
                             DetectorConfig(persistence=2, clear_windows=2,
                                            z_threshold=2.5))

    def test_simulated_sequence(self):
        assert_scorers_agree(simulated_sequence())

    def test_nan_sensor_rows(self):
        assert_scorers_agree(nan_sensor_sequence())

    def test_jax_backend_agrees(self):
        # the shardable XLA path (node axis partitions over repro.dist)
        assert_scorers_agree(scripted_sequence(), backend="jax")
        assert_scorers_agree(nan_sensor_sequence(), backend="jax")

    def test_pallas_matches_per_node_reference(self):
        # transitively: pallas == numpy == per-node reference, but pin
        # the direct comparison too
        assert_equivalent(scripted_sequence(),
                          DetectorConfig(scorer="pallas"))

    @pytest.mark.scale
    @pytest.mark.parametrize("n", [4097, 8192])
    def test_big_fleet(self, n):
        # 4097 exercises lane-padding remainders; 8192 a full-lane fleet
        rng = np.random.RandomState(n)
        frames = []
        for step in range(8):
            t = 10.0 * (1 + rng.normal(0, 0.003, n))
            t[n // 3] *= 1.25                 # one sustained straggler
            if step == 5:
                t[7] *= 40.0                  # transient stall
            frames.append(full_frame(step, t, n=n))
        assert_scorers_agree(frames)


class TestRunWindowVsRunStepDeterminism:
    """Satellite: fixed-seed determinism of run_window vs run_step."""

    def test_fixed_seed_bitwise_equal(self):
        a = SimCluster(16, 2, seed=3)
        b = SimCluster(16, 2, seed=3)
        wa = []
        wb = []
        for _ in range(20):
            wa.append(a.run_window(6)["step_times"])
            wb.append(np.asarray([b.run_step()["step_time"]
                                  for _ in range(6)]))
        np.testing.assert_array_equal(np.concatenate(wa),
                                      np.concatenate(wb))
        assert a.t == b.t and a.step == b.step
        fa, fb = a.collect(), b.collect()
        for m in fa.metrics:
            np.testing.assert_array_equal(fa.metrics[m], fb.metrics[m],
                                          err_msg=m)

    def test_repeated_run_window_deterministic(self):
        def trace(seed):
            c = SimCluster(16, 2, rates=FaultRates(congestion=0.3),
                           seed=seed)
            out = []
            for _ in range(30):
                out.append(c.run_window(6)["step_times"])
            return np.concatenate(out)
        np.testing.assert_array_equal(trace(5), trace(5))
        assert not np.array_equal(trace(5), trace(6))
