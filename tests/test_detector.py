"""Unit tests for the peer-relative straggler detector (§4.2)."""
import numpy as np

from repro.core import (Action, DetectorConfig, OnlineMonitor, PolicyConfig,
                        StragglerDetector, TieredPolicy, robust_z)
from repro.core.telemetry import Frame


def mk_frame(step, step_times, temps=None, n=None, valid=None):
    n = n or len(step_times)
    metrics = {
        "step_time": np.asarray(step_times, float),
        "gpu_temp": np.asarray(temps if temps is not None
                               else np.full(n, 58.0), float),
        "gpu_util": np.full(n, 0.97),
        "gpu_freq": np.full(n, 1.93),
        "gpu_power": np.full(n, 350.0),
        "nic_errors": np.zeros(n),
        "nic_tx_rate": np.full(n, 50.0),
        "nic_up": np.ones(n),
    }
    return Frame(t=float(step * 60), step=step,
                 node_ids=np.arange(n, dtype=np.int64), metrics=metrics,
                 valid=np.ones(n, bool) if valid is None else valid)


def feed(det, times_fn, windows, n=16):
    out = []
    for w in range(windows):
        out = det.update(mk_frame(w, times_fn(w)))
    return out


class TestRobustZ:
    def test_outlier_scores_high(self):
        v = np.array([10.0] * 15 + [13.0])
        z = robust_z(v)
        assert z[-1] > 10
        assert np.all(np.abs(z[:-1]) < 3), z[:-1]

    def test_symmetric_noise_scores_low(self):
        rng = np.random.RandomState(0)
        v = 10 + rng.normal(0, 0.1, 64)
        assert np.max(np.abs(robust_z(v))) < 6


class TestDetector:
    def test_no_flags_on_healthy_fleet(self):
        det = StragglerDetector()
        rng = np.random.RandomState(1)
        res = feed(det, lambda w: 10 + rng.normal(0, 0.1, 16), 12)
        assert not any(a.flagged for a in res)

    def test_sustained_straggler_flagged(self):
        det = StragglerDetector()
        times = lambda w: [10.0] * 15 + [12.0]
        res = feed(det, times, 6)
        by = {a.node_id: a for a in res}
        assert by[15].flagged
        assert by[15].step_deviant
        assert 0.15 < by[15].slowdown < 0.25
        assert not any(a.flagged for a in res if a.node_id != 15)

    def test_transient_spike_not_flagged(self):
        det = StragglerDetector(DetectorConfig(persistence=3))
        # node 7 spikes for only 2 of 8 windows
        def times(w):
            t = [10.0] * 16
            if w in (3, 4):
                t[7] = 14.0
            return t
        res = feed(det, times, 8)
        assert not any(a.flagged for a in res)

    def test_needs_full_window_before_flagging(self):
        det = StragglerDetector(DetectorConfig(persistence=4))
        res = feed(det, lambda w: [10.0] * 15 + [13.0], 2)
        assert not any(a.step_deviant for a in res)

    def test_stall_flagged_immediately(self):
        det = StragglerDetector()
        f = mk_frame(0, [10.0] * 15 + [100.0])
        res = det.update(f)
        assert res[15].stalled and res[15].flagged

    def test_missing_heartbeat_is_stall(self):
        det = StragglerDetector()
        valid = np.ones(16, bool)
        valid[3] = False
        res = det.update(mk_frame(0, [10.0] * 16, valid=valid))
        assert res[3].stalled

    def test_hysteresis_clears_after_clean_windows(self):
        det = StragglerDetector(DetectorConfig(clear_windows=3))
        feed(det, lambda w: [10.0] * 15 + [12.5], 6)
        res = feed(det, lambda w: [10.0] * 16, 3)
        assert {a.node_id: a.flagged for a in res}[15]   # still latched
        # the stale deviant windows must age out of the history (window=6)
        # AND clear_windows clean evaluations must accumulate
        res = feed(det, lambda w: [10.0] * 16, 6)
        assert not {a.node_id: a.flagged for a in res}[15]

    def test_hardware_only_flag_needs_multiple_signals(self):
        det = StragglerDetector(DetectorConfig(min_support=2))
        # only temperature deviates -> no flag
        for w in range(8):
            f = mk_frame(w, [10.0] * 16,
                         temps=[58.0] * 10 + [80.0] + [58.0] * 5)
            res = det.update(f)
        assert not res[10].flagged
        assert res[10].support == ["gpu_temp"]

    def test_membership_change_resets_history(self):
        det = StragglerDetector()
        feed(det, lambda w: [10.0] * 15 + [12.5], 6, n=16)
        det.update(mk_frame(99, [10.0] * 12))    # 12-node fleet now
        assert len(det.history) == 1

    def test_replacement_does_not_inherit_history(self):
        """A swapped-in spare must not be flagged off its predecessor's
        slow history column (regression: replacement cascade)."""
        det = StragglerDetector()
        # node 15 is slow for 6 windows, then gets replaced by node 99
        feed(det, lambda w: [10.0] * 15 + [13.0], 6)
        f = mk_frame(10, [10.0] * 16)
        f.node_ids = np.array(list(range(15)) + [99], dtype=np.int64)
        res = det.update(f)
        by = {a.node_id: a for a in res}
        assert not by[99].step_deviant
        assert not by[99].flagged


class TestPolicy:
    def _assess(self, slowdown, stalled=False, support=()):
        from repro.core.detector import NodeAssessment
        return NodeAssessment(0, slowdown, stalled, list(support),
                              slowdown > 0, True)

    def test_tiers(self):
        pol = TieredPolicy(PolicyConfig())
        assert pol.decide([self._assess(0.25)])[0].action == \
            Action.IMMEDIATE_RESTART
        assert pol.decide([self._assess(0.12)])[0].action == \
            Action.DEFER_TO_CHECKPOINT
        assert pol.decide([self._assess(0.0, support=["gpu_temp",
                                                      "gpu_freq"])])[0] \
            .action == Action.PENDING_VERIFICATION
        assert pol.decide([self._assess(0.0, stalled=True)])[0].action == \
            Action.IMMEDIATE_RESTART

    def test_unflagged_ignored(self):
        from repro.core.detector import NodeAssessment
        pol = TieredPolicy()
        a = NodeAssessment(0, 0.5, False, [], True, flagged=False)
        assert pol.decide([a]) == []


class TestMonitor:
    def test_pending_emitted_once(self):
        mon = OnlineMonitor(DetectorConfig(persistence=3, min_support=2))
        events = []
        for w in range(10):
            f = mk_frame(w, [10.0] * 16)
            f.metrics["gpu_temp"][5] = 85.0
            f.metrics["gpu_freq"][5] = 1.3
            events += mon.observe(f)
        pends = [e for e in events
                 if e.decision.action == Action.PENDING_VERIFICATION]
        assert len(pends) == 1 and pends[0].decision.node_id == 5
